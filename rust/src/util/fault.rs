#![warn(missing_docs)]
//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] decides, for each batch-execution *attempt* the scoring
//! server makes, whether to run it cleanly or inject a failure: a transient
//! engine error, a fatal engine error, a slow batch (stall), or a worker
//! panic. Two modes exist:
//!
//! * **Seeded** (`MERGEMOE_FAULT=seed:42,transient:0.2,panic:0.05,…`): the
//!   action at attempt `i` is a pure function of `(seed, i)`, so the same
//!   seed always produces the same failure schedule — chaos testing that is
//!   a reproducible regression test, not a flake generator
//!   (`same_seed_same_schedule` pins this; see the ARCHITECTURE.md ledger).
//! * **Scripted** ([`FaultPlan::scripted`]): tests hand the exact action
//!   sequence, attempt by attempt, for surgical scenarios (stall the worker,
//!   then panic, then run clean).
//!
//! Either mode may additionally carry a **poison token**: any attempt whose
//! batch contains that token fails transiently, which is how the batch-split
//! isolation path ("one poison request cannot fail its batchmates") is
//! exercised deterministically.
//!
//! When `MERGEMOE_FAULT` is unset, [`FaultPlan::from_env`] returns `None`
//! and the server runs the exact pre-existing execution — no plan object,
//! no per-batch draws, no extra allocations.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

/// What to do with one batch-execution attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Run the attempt normally.
    None,
    /// Fail the attempt with a retryable engine error.
    Transient,
    /// Fail the attempt with a non-retryable engine error.
    Fatal,
    /// Stall the worker for the given duration, then run normally.
    Slow(Duration),
    /// Panic the worker thread mid-attempt.
    Panic,
}

/// Retry class of a batch failure (see [`classify`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    /// Worth retrying (and, on repeat failure, splitting the batch).
    Transient,
    /// Fail fast; retrying would waste compute.
    Fatal,
}

/// The typed error produced by injected engine faults; [`classify`]
/// recognizes it by downcast so injected and organic failures flow through
/// the same retry machinery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    /// Retry class of this injected failure.
    pub class: FaultClass,
}

impl std::fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.class {
            FaultClass::Transient => write!(f, "injected transient engine fault"),
            FaultClass::Fatal => write!(f, "injected fatal engine fault"),
        }
    }
}

impl std::error::Error for InjectedFault {}

/// Classify an engine error for the retry layer: injected faults carry
/// their class; everything else defaults to [`FaultClass::Transient`] —
/// retries are capped and batch splitting bounds the damage, while a
/// misclassified genuinely-transient device error would otherwise fail
/// requests needlessly.
pub fn classify(e: &anyhow::Error) -> FaultClass {
    match e.downcast_ref::<InjectedFault>() {
        Some(f) => f.class,
        None => FaultClass::Transient,
    }
}

// ---------------------------------------------------------------------------
// IO fail-points (registry atomic-write crash simulation)
// ---------------------------------------------------------------------------

/// Fail-at crossing index; negative = disarmed. Process-global on purpose:
/// the gates sit deep in the registry write path and a simulated crash is
/// a whole-process property, exactly like a real `kill -9`.
static IO_FAIL_AT: AtomicI64 = AtomicI64::new(-1);
/// Gate crossings since the last [`arm_io_fail`] call.
static IO_CROSSINGS: AtomicU64 = AtomicU64::new(0);

/// The typed error produced when an armed IO fail-point fires; carries the
/// gate label (e.g. `"registry.fsync.weights"`) for assertions and logs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedIoFault {
    /// Label of the gate that fired.
    pub label: &'static str,
}

impl std::fmt::Display for InjectedIoFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected IO fault at gate {:?}", self.label)
    }
}

impl std::error::Error for InjectedIoFault {}

/// Arm (or with `None`, disarm) the global IO fail-point and reset the
/// crossing counter. `Some(n)` makes the `n`-th (0-based) subsequent
/// [`io_gate`] crossing fail; all other crossings pass. Tests sweep `n`
/// over `0..crossings_of_a_clean_run` to kill the writer at every
/// fsync/rename point in turn.
pub fn arm_io_fail(fail_at: Option<u64>) {
    IO_CROSSINGS.store(0, Ordering::SeqCst);
    IO_FAIL_AT.store(fail_at.map_or(-1, |n| n as i64), Ordering::SeqCst);
}

/// Crossings counted since the last [`arm_io_fail`] — run a clean pass
/// first to learn how many kill points a code path has.
pub fn io_crossings() -> u64 {
    IO_CROSSINGS.load(Ordering::SeqCst)
}

/// A named crash point on a durability-critical IO path. Free when
/// disarmed (one relaxed load + add); when armed, the scheduled crossing
/// returns a typed [`InjectedIoFault`] which callers propagate — the write
/// aborts exactly as if the process died there, minus the exit.
pub fn io_gate(label: &'static str) -> Result<()> {
    let i = IO_CROSSINGS.fetch_add(1, Ordering::SeqCst);
    let at = IO_FAIL_AT.load(Ordering::SeqCst);
    if at >= 0 && i == at as u64 {
        return Err(InjectedIoFault { label }.into());
    }
    Ok(())
}

/// Probabilities (per attempt) for the seeded mode.
#[derive(Debug, Clone, Copy)]
struct Rates {
    transient: f64,
    fatal: f64,
    panic: f64,
    slow: f64,
    slow_ms: u64,
}

impl Default for Rates {
    fn default() -> Self {
        Rates { transient: 0.05, fatal: 0.0, panic: 0.0, slow: 0.0, slow_ms: 10 }
    }
}

#[derive(Debug)]
enum Mode {
    Seeded { seed: u64, rates: Rates },
    Scripted { actions: Vec<FaultAction> },
}

/// A deterministic fault schedule. Thread-safe: the server consults it via
/// [`FaultPlan::next`], which advances an atomic attempt cursor. Variant
/// *cache builds* draw from a separate cursor ([`FaultPlan::next_build`])
/// so a chaos plan can perturb both compute attempts and cold-build
/// attempts without the two schedules aliasing each other.
#[derive(Debug)]
pub struct FaultPlan {
    mode: Mode,
    cursor: AtomicU64,
    poison: Option<i32>,
    io_fail: Option<u64>,
    /// `build-fail:N` — fail the `N`-th (0-based) cache build attempt.
    build_fail: Option<u64>,
    /// Exact per-build-attempt script (takes precedence over `build_fail`).
    build_script: Option<Vec<FaultAction>>,
    build_cursor: AtomicU64,
}

impl FaultPlan {
    /// Seed-driven plan with the given per-attempt fault rates (see the
    /// `MERGEMOE_FAULT` grammar on [`FaultPlan::parse`]).
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan {
            mode: Mode::Seeded { seed, rates: Rates::default() },
            cursor: AtomicU64::new(0),
            poison: None,
            io_fail: None,
            build_fail: None,
            build_script: None,
            build_cursor: AtomicU64::new(0),
        }
    }

    /// Exact per-attempt script; attempts past the end run clean.
    pub fn scripted(actions: Vec<FaultAction>) -> FaultPlan {
        FaultPlan {
            mode: Mode::Scripted { actions },
            cursor: AtomicU64::new(0),
            poison: None,
            io_fail: None,
            build_fail: None,
            build_script: None,
            build_cursor: AtomicU64::new(0),
        }
    }

    /// Mark `token` as poisoned: any attempt whose batch contains it fails
    /// transiently (scheduled actions take precedence).
    pub fn with_poison(mut self, token: i32) -> FaultPlan {
        self.poison = Some(token);
        self
    }

    /// Exact per-*build*-attempt script for the variant cache: build
    /// attempt `i` takes `actions[i]` (past the end = clean). Takes
    /// precedence over `build-fail:N`. Tests use this to force a fatal
    /// first build (immediate quarantine) or `Transient × (retries+1)`
    /// (retry-exhaustion quarantine) deterministically.
    pub fn with_build_script(mut self, actions: Vec<FaultAction>) -> FaultPlan {
        self.build_script = Some(actions);
        self
    }

    /// Parse the `MERGEMOE_FAULT` grammar: comma-separated `key:value`
    /// pairs. `seed:N` selects seeded mode (required); optional rates
    /// `transient:P`, `fatal:P`, `panic:P`, `slow:P` (probabilities in
    /// `[0,1]`, defaults `0.05/0/0/0`), `slow-ms:N` (stall length, default
    /// 10), `poison:TOK` (poison token id), `io-fail:N` (fail the
    /// `N`-th IO gate crossing — armed via [`FaultPlan::arm_io`], used by
    /// `mergemoe registry` to simulate a crash mid-persist), and
    /// `build-fail:N` (fail the `N`-th variant-cache build attempt with a
    /// transient fault, exercising the cache's retry-under-backoff path).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut seed: Option<u64> = None;
        let mut rates = Rates::default();
        let mut poison = None;
        let mut io_fail = None;
        let mut build_fail = None;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once(':')
                .with_context(|| format!("fault spec entry {part:?} is not key:value"))?;
            let fv = || -> Result<f64> {
                let p: f64 = v.parse().with_context(|| format!("bad rate {v:?} for {k}"))?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("rate {k}:{v} outside [0,1]");
                }
                Ok(p)
            };
            match k {
                "seed" => seed = Some(v.parse().with_context(|| format!("bad seed {v:?}"))?),
                "transient" => rates.transient = fv()?,
                "fatal" => rates.fatal = fv()?,
                "panic" => rates.panic = fv()?,
                "slow" => rates.slow = fv()?,
                "slow-ms" => {
                    rates.slow_ms = v.parse().with_context(|| format!("bad slow-ms {v:?}"))?
                }
                "poison" => {
                    poison = Some(v.parse().with_context(|| format!("bad poison token {v:?}"))?)
                }
                "io-fail" => {
                    io_fail =
                        Some(v.parse().with_context(|| format!("bad io-fail index {v:?}"))?)
                }
                "build-fail" => {
                    build_fail = Some(
                        v.parse().with_context(|| format!("bad build-fail index {v:?}"))?,
                    )
                }
                other => bail!("unknown fault spec key {other:?}"),
            }
        }
        let seed = seed.context("fault spec needs seed:N")?;
        let total = rates.transient + rates.fatal + rates.panic + rates.slow;
        if total > 1.0 {
            bail!("fault rates sum to {total} > 1");
        }
        Ok(FaultPlan {
            mode: Mode::Seeded { seed, rates },
            cursor: AtomicU64::new(0),
            poison,
            io_fail,
            build_fail,
            build_script: None,
            build_cursor: AtomicU64::new(0),
        })
    }

    /// Arm the process-global IO fail-point from this plan's `io-fail:N`
    /// entry (no-op when absent). Called by the `registry` CLI entry point
    /// so `MERGEMOE_FAULT=seed:1,io-fail:3 mergemoe registry add …`
    /// simulates a crash at the 3rd durability gate.
    pub fn arm_io(&self) {
        if self.io_fail.is_some() {
            arm_io_fail(self.io_fail);
        }
    }

    /// Build a plan from `MERGEMOE_FAULT`, or `None` when unset/empty. A
    /// malformed value is a hard error — silently running *without* the
    /// faults a chaos run asked for would make failures look like passes.
    pub fn from_env() -> Result<Option<Arc<FaultPlan>>> {
        match std::env::var("MERGEMOE_FAULT") {
            Ok(spec) if !spec.trim().is_empty() => {
                let plan =
                    FaultPlan::parse(&spec).context("parsing MERGEMOE_FAULT")?;
                Ok(Some(Arc::new(plan)))
            }
            _ => Ok(None),
        }
    }

    /// The action for attempt `i` — pure, does not advance the cursor.
    pub fn action_at(&self, i: u64) -> FaultAction {
        match &self.mode {
            Mode::Scripted { actions } => {
                actions.get(i as usize).copied().unwrap_or(FaultAction::None)
            }
            Mode::Seeded { seed, rates } => {
                // One independent draw per attempt index: the schedule is a
                // pure function of (seed, i), insensitive to how many
                // attempts actually ran before this one was inspected.
                let mut rng = Rng::new(seed ^ i.wrapping_mul(0xA24B_AED4_963E_E407));
                let u = rng.f64();
                let mut edge = rates.transient;
                if u < edge {
                    return FaultAction::Transient;
                }
                edge += rates.fatal;
                if u < edge {
                    return FaultAction::Fatal;
                }
                edge += rates.panic;
                if u < edge {
                    return FaultAction::Panic;
                }
                edge += rates.slow;
                if u < edge {
                    return FaultAction::Slow(Duration::from_millis(rates.slow_ms));
                }
                FaultAction::None
            }
        }
    }

    /// Consume and return the next attempt's action.
    pub fn next(&self) -> FaultAction {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.action_at(i)
    }

    /// Attempts consumed so far.
    pub fn attempts(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Consume and return the next *cache build* attempt's action. A
    /// separate cursor from [`FaultPlan::next`]: with a `build_script`,
    /// build attempt `i` takes `script[i]`; otherwise `build-fail:N` fails
    /// the `N`-th build attempt with [`FaultAction::Transient`] (so under
    /// chaos sweeps the retry path — not a permanent quarantine — is
    /// exercised, and the run still completes). Everything else runs clean.
    pub fn next_build(&self) -> FaultAction {
        let i = self.build_cursor.fetch_add(1, Ordering::Relaxed);
        if let Some(script) = &self.build_script {
            return script.get(i as usize).copied().unwrap_or(FaultAction::None);
        }
        match self.build_fail {
            Some(n) if n == i => FaultAction::Transient,
            _ => FaultAction::None,
        }
    }

    /// Build attempts consumed so far (via [`FaultPlan::next_build`]).
    pub fn build_attempts(&self) -> u64 {
        self.build_cursor.load(Ordering::Relaxed)
    }

    /// Whether this batch trips the poison-token condition.
    pub fn is_poisoned(&self, tokens: &[i32]) -> bool {
        match self.poison {
            Some(p) => tokens.contains(&p),
            None => false,
        }
    }

    /// The first `n` actions of the schedule (pure; for pinning tests and
    /// debugging a chaos run).
    pub fn schedule(&self, n: u64) -> Vec<FaultAction> {
        (0..n).map(|i| self.action_at(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let a = FaultPlan::parse("seed:42,transient:0.3,panic:0.1,slow:0.05").unwrap();
        let b = FaultPlan::parse("seed:42,transient:0.3,panic:0.1,slow:0.05").unwrap();
        assert_eq!(a.schedule(512), b.schedule(512));
        let c = FaultPlan::parse("seed:43,transient:0.3,panic:0.1,slow:0.05").unwrap();
        assert_ne!(a.schedule(512), c.schedule(512), "different seeds must differ");
    }

    #[test]
    fn next_walks_the_schedule_in_order() {
        let p = FaultPlan::seeded(7);
        let want = p.schedule(64);
        let got: Vec<FaultAction> = (0..64).map(|_| p.next()).collect();
        assert_eq!(got, want);
        assert_eq!(p.attempts(), 64);
    }

    #[test]
    fn rates_shape_the_mix() {
        let p = FaultPlan::parse("seed:5,transient:1.0").unwrap();
        assert!(p.schedule(32).iter().all(|a| *a == FaultAction::Transient));
        let q = FaultPlan::parse("seed:5,transient:0.0").unwrap();
        assert!(q.schedule(32).iter().all(|a| *a == FaultAction::None));
        let r = FaultPlan::parse("seed:5,transient:0.5").unwrap();
        let n_faulty =
            r.schedule(1000).iter().filter(|a| **a == FaultAction::Transient).count();
        assert!((300..700).contains(&n_faulty), "p=0.5 gave {n_faulty}/1000");
    }

    #[test]
    fn scripted_plans_run_exactly_then_go_clean() {
        let p = FaultPlan::scripted(vec![
            FaultAction::Transient,
            FaultAction::Slow(Duration::from_millis(3)),
        ]);
        assert_eq!(p.next(), FaultAction::Transient);
        assert_eq!(p.next(), FaultAction::Slow(Duration::from_millis(3)));
        assert_eq!(p.next(), FaultAction::None);
        assert_eq!(p.next(), FaultAction::None);
    }

    #[test]
    fn poison_token_detection() {
        let p = FaultPlan::scripted(vec![]).with_poison(9);
        assert!(p.is_poisoned(&[1, 9, 3]));
        assert!(!p.is_poisoned(&[1, 2, 3]));
        let q = FaultPlan::scripted(vec![]);
        assert!(!q.is_poisoned(&[9]));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("transient:0.5").is_err(), "seed required");
        assert!(FaultPlan::parse("seed:x").is_err());
        assert!(FaultPlan::parse("seed:1,transient:1.5").is_err());
        assert!(FaultPlan::parse("seed:1,transient:0.8,fatal:0.8").is_err());
        assert!(FaultPlan::parse("seed:1,wat:2").is_err());
        assert!(FaultPlan::parse("seed:1,noval").is_err());
    }

    #[test]
    fn build_fail_fires_at_exactly_the_named_attempt() {
        let p = FaultPlan::parse("seed:1,build-fail:2").unwrap();
        assert_eq!(p.next_build(), FaultAction::None);
        assert_eq!(p.next_build(), FaultAction::None);
        assert_eq!(p.next_build(), FaultAction::Transient);
        assert_eq!(p.next_build(), FaultAction::None);
        assert_eq!(p.build_attempts(), 4);
        // the build cursor is independent of the batch-attempt cursor
        assert_eq!(p.attempts(), 0);
        // plans without build-fail never fail builds
        let q = FaultPlan::parse("seed:1,transient:1.0").unwrap();
        assert!((0..16).all(|_| q.next_build() == FaultAction::None));
    }

    #[test]
    fn build_script_takes_precedence_and_runs_exactly() {
        let p = FaultPlan::scripted(vec![]).with_build_script(vec![
            FaultAction::Fatal,
            FaultAction::Transient,
        ]);
        assert_eq!(p.next_build(), FaultAction::Fatal);
        assert_eq!(p.next_build(), FaultAction::Transient);
        assert_eq!(p.next_build(), FaultAction::None);
    }

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse("seed:9,transient:0.2,fatal:0.1,panic:0.05,slow:0.1,slow-ms:25,poison:4")
            .unwrap();
        assert!(p.is_poisoned(&[4]));
        // every action kind is reachable under these rates
        let sched = p.schedule(4096);
        assert!(sched.contains(&FaultAction::Transient));
        assert!(sched.contains(&FaultAction::Fatal));
        assert!(sched.contains(&FaultAction::Panic));
        assert!(sched.contains(&FaultAction::Slow(Duration::from_millis(25))));
        assert!(sched.contains(&FaultAction::None));
    }

    /// The IO gate is process-global, so the tests that arm it must not
    /// interleave (cargo runs tests on parallel threads).
    static IO_GATE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn io_gate_fires_at_exactly_the_armed_crossing() {
        let _g = IO_GATE_LOCK.lock().unwrap();
        arm_io_fail(Some(2));
        assert!(io_gate("a").is_ok());
        assert!(io_gate("b").is_ok());
        let err = io_gate("c").unwrap_err();
        let inj = err.downcast_ref::<InjectedIoFault>().expect("typed IO fault");
        assert_eq!(inj.label, "c");
        assert!(io_gate("d").is_ok(), "only the armed crossing fails");
        assert_eq!(io_crossings(), 4);
        arm_io_fail(None);
        assert_eq!(io_crossings(), 0);
        assert!(io_gate("e").is_ok());
        arm_io_fail(None);
    }

    #[test]
    fn parse_io_fail_key_arms_on_request() {
        let _g = IO_GATE_LOCK.lock().unwrap();
        let p = FaultPlan::parse("seed:1,io-fail:0").unwrap();
        arm_io_fail(None);
        p.arm_io();
        assert!(io_gate("x").is_err());
        arm_io_fail(None);
        // plans without io-fail never touch the global
        arm_io_fail(Some(0));
        FaultPlan::parse("seed:1").unwrap().arm_io();
        assert!(io_gate("y").is_err(), "arm_io without io-fail is a no-op");
        arm_io_fail(None);
    }

    #[test]
    fn classify_routes_injected_and_unknown_errors() {
        let t: anyhow::Error = InjectedFault { class: FaultClass::Transient }.into();
        let f: anyhow::Error = InjectedFault { class: FaultClass::Fatal }.into();
        let o = anyhow::anyhow!("device hiccup");
        assert_eq!(classify(&t), FaultClass::Transient);
        assert_eq!(classify(&f), FaultClass::Fatal);
        assert_eq!(classify(&o), FaultClass::Transient);
    }
}
