//! Deterministic pseudo-random number generation (`rand` is unavailable in
//! the offline build, and determinism across runs is a feature: every
//! experiment in EXPERIMENTS.md is reproducible bit-for-bit).
//!
//! [`Rng`] is SplitMix64 — passes BigCrush-level statistical quality for the
//! simulation workloads here (task generation, property tests, workload
//! traces), is trivially seedable and splittable.

/// SplitMix64 PRNG with convenience samplers.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent child stream (used to give each task/layer its
    /// own generator without sequencing constraints).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. Uses rejection sampling to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller (used by property tests and synthetic
    /// weight generation).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_uniform_mean() {
        let mut r = Rng::new(1);
        let m: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(5);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
