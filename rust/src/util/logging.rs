//! Structured stderr logging with levels, controlled by `MERGEMOE_LOG`
//! (`error|warn|info|debug`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // info
static INIT: std::sync::Once = std::sync::Once::new();
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn init() {
    INIT.call_once(|| {
        START.get_or_init(Instant::now);
        let lvl = match std::env::var("MERGEMOE_LOG").as_deref() {
            Ok("error") => 0,
            Ok("warn") => 1,
            Ok("debug") => 3,
            _ => 2,
        };
        LEVEL.store(lvl, Ordering::Relaxed);
    });
}

pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn log_does_not_panic() {
        init();
        log(Level::Info, "test", "hello");
        crate::info!("formatted {}", 42);
    }
}
