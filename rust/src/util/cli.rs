//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Model: `binary <subcommand> [--flag value] [--switch] [positional...]`.
//! Flags may be given as `--key value` or `--key=value`.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]). `known_switches` lists
    /// boolean flags that take no value.
    pub fn parse(argv: &[String], known_switches: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&stripped) {
                    out.switches.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .with_context(|| format!("flag --{stripped} expects a value"))?;
                    out.flags.insert(stripped.to_string(), v.clone());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(known_switches: &[&str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, known_switches)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{key}"),
        }
    }

    /// Resolve and apply the worker-thread budget: an explicit `--threads N`
    /// flag overrides the `MERGEMOE_THREADS` environment variable, which
    /// overrides core-count auto-detection (see `util::par`). Returns the
    /// effective thread count.
    pub fn apply_threads(&self) -> Result<usize> {
        if let Some(v) = self.get("threads") {
            let n: usize = v
                .parse()
                .with_context(|| format!("--threads expects a positive integer, got {v:?}"))?;
            if n == 0 {
                bail!("--threads must be >= 1");
            }
            crate::util::par::set_max_threads(n);
        }
        Ok(crate::util::par::max_threads())
    }

    /// Millisecond-valued duration flag, e.g. `--deadline-ms 250`.
    pub fn ms(&self, key: &str, default: Duration) -> Result<Duration> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                let n: u64 = v
                    .parse()
                    .with_context(|| format!("--{key} expects milliseconds, got {v:?}"))?;
                Ok(Duration::from_millis(n))
            }
        }
    }

    /// Optional millisecond flag: absent (or explicit `0`) means "none" —
    /// the convention for disabling deadlines.
    pub fn opt_ms(&self, key: &str) -> Result<Option<Duration>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => {
                let n: u64 = v
                    .parse()
                    .with_context(|| format!("--{key} expects milliseconds, got {v:?}"))?;
                Ok((n > 0).then(|| Duration::from_millis(n)))
            }
        }
    }

    /// Comma-separated integer list flag, e.g. `--ms 6,8` (the sweep's
    /// target expert counts).
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .with_context(|| format!("--{key} expects integers, got {v:?}"))
                })
                .collect(),
        }
    }

    /// Comma-separated list flag, e.g. `--tasks copy,rev`.
    pub fn list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(
            &sv(&["eval", "--model", "beta", "--verbose", "--n=5", "extra"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("eval"));
        assert_eq!(a.get("model"), Some("beta"));
        assert!(a.has("verbose"));
        assert_eq!(a.usize("n", 0).unwrap(), 5);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["x", "--flag"]), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&["run"]), &[]).unwrap();
        assert_eq!(a.usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_or("mode", "fast"), "fast");
        assert_eq!(a.list("tasks", &["a", "b"]), vec!["a", "b"]);
    }

    #[test]
    fn threads_flag_applies_and_validates() {
        let prev = crate::util::par::max_threads();
        let a = Args::parse(&sv(&["run", "--threads", "3"]), &[]).unwrap();
        assert_eq!(a.apply_threads().unwrap(), 3);
        assert_eq!(crate::util::par::max_threads(), 3);
        crate::util::par::set_max_threads(prev);
        let bad = Args::parse(&sv(&["run", "--threads", "0"]), &[]).unwrap();
        assert!(bad.apply_threads().is_err());
        let nan = Args::parse(&sv(&["run", "--threads", "lots"]), &[]).unwrap();
        assert!(nan.apply_threads().is_err());
    }

    #[test]
    fn duration_flags() {
        let a = Args::parse(&sv(&["serve", "--deadline-ms", "250", "--drain-ms=0"]), &[]).unwrap();
        assert_eq!(a.ms("deadline-ms", Duration::ZERO).unwrap(), Duration::from_millis(250));
        assert_eq!(a.ms("absent", Duration::from_millis(7)).unwrap(), Duration::from_millis(7));
        assert_eq!(a.opt_ms("deadline-ms").unwrap(), Some(Duration::from_millis(250)));
        assert_eq!(a.opt_ms("drain-ms").unwrap(), None, "explicit 0 disables");
        assert_eq!(a.opt_ms("absent").unwrap(), None);
        let bad = Args::parse(&sv(&["serve", "--deadline-ms", "soon"]), &[]).unwrap();
        assert!(bad.ms("deadline-ms", Duration::ZERO).is_err());
    }

    #[test]
    fn list_flag() {
        let a = Args::parse(&sv(&["run", "--tasks", "copy, rev,sort"]), &[]).unwrap();
        assert_eq!(a.list("tasks", &[]), vec!["copy", "rev", "sort"]);
    }

    #[test]
    fn usize_list_flag() {
        let a = Args::parse(&sv(&["run", "--ms", "6, 8"]), &[]).unwrap();
        assert_eq!(a.usize_list("ms", &[]).unwrap(), vec![6, 8]);
        assert_eq!(a.usize_list("absent", &[4, 2]).unwrap(), vec![4, 2]);
        let bad = Args::parse(&sv(&["run", "--ms", "6,x"]), &[]).unwrap();
        assert!(bad.usize_list("ms", &[]).is_err());
    }
}
