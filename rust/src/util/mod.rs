//! Substrate utilities reimplemented for the offline environment:
//! deterministic RNG, JSON, CLI parsing, logging and small helpers.

pub mod cli;
pub mod fault;
pub mod json;
pub mod logging;
pub mod par;
pub mod rng;
pub mod sha256;

/// Monotonic wall-clock helper used by metrics and the bench harness.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}

/// Format a byte count human-readably (metrics/report output).
pub fn human_bytes(n: usize) -> String {
    let f = n as f64;
    if f >= 1e9 {
        format!("{:.2} GB", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2} MB", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.2} KB", f / 1e3)
    } else {
        format!("{} B", n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(1_500), "1.50 KB");
        assert_eq!(human_bytes(2_500_000), "2.50 MB");
        assert_eq!(human_bytes(3_210_000_000), "3.21 GB");
    }
}
