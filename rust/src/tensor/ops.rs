//! Tensor operations: the runtime-dispatched GEMM family plus the
//! neural-net primitives the native engine needs (softmax, layernorm, silu,
//! top-k).
//!
//! The matmul family is the native engine's hot path. Since the kernel
//! layer landed, every variant validates shapes here and dispatches to
//! [`crate::kernel`] — runtime-selected SIMD microkernels (AVX2+FMA on
//! x86_64, NEON on aarch64, seed-exact scalar fallback; `MERGEMOE_KERNEL`
//! overrides), parallelized over output rows:
//!
//! * [`matmul`]    — dense `a @ b`; cache-blocked and panel-packed on the
//!   AVX2 path at large shapes.
//! * [`matmul_bt`] — `a @ bᵀ` (every linear layer uses the `y = x Wᵀ`
//!   convention; both operands stream contiguously, so this form never
//!   needs packing).
//! * [`matmul_at`] — `aᵀ @ b`; keeps the zero-skip because its `a` operands
//!   (Theorem-1 usage/assignment masses) are the ones that arrive sparse.
//!
//! Fused-epilogue variants eliminate a full write+re-read of an
//! intermediate matrix each:
//!
//! * [`swiglu_bt_into`]            — `silu(x W_Gᵀ) ⊙ (x W_Uᵀ)` in one pass
//!   (the expert FFN; the U panel is never materialized);
//! * [`matmul_bt_scaled_add_into`] — `out += α · a @ bᵀ` (shared-expert
//!   residual, frequency-weighted Ŷ panels);
//! * [`matmul_bt_scatter_add_into`] — `out[dst_r] += w_r · a_r @ bᵀ`
//!   (merged-expert output recombination);
//! * [`syrk_bt`]                   — the symmetric rank-k Gram update
//!   `P Pᵀ`, computing the lower triangle and mirroring it.
//!
//! Every variant has a `*_into` twin that writes a caller-owned output
//! tensor, so steady-state serving loops can run without per-call
//! allocation. Overwriting variants fully overwrite — buffers need not be
//! zeroed; `*_add_into` variants accumulate.
//!
//! Determinism: the kernel choice is fixed per process and each output
//! element is reduced in an order that depends only on shapes, so results
//! are bit-identical for any `MERGEMOE_THREADS` setting
//! (`tests/par_consistency.rs`); scalar-vs-SIMD agreement is pinned to
//! tolerance in `tests/kernel_consistency.rs`.

use anyhow::{bail, Result};

use super::Tensor;
use crate::kernel;
use crate::util::par;

/// `a (m,k) @ b (k,n) -> (m,n)`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, _) = mat_dims(a)?;
    let (_, n) = mat_dims(b)?;
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(a, b, &mut out)?;
    Ok(out)
}

/// [`matmul`] into a preallocated `(m,n)` output (fully overwritten).
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) -> Result<()> {
    let (m, k) = mat_dims(a)?;
    let (k2, n) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul inner dim mismatch: {:?} @ {:?}", a.shape(), b.shape());
    }
    check_out_shape("matmul", out, m, n)?;
    if m == 0 || n == 0 {
        return Ok(());
    }
    kernel::gemm_nn(a.data(), b.data(), m, k, n, out.data_mut());
    Ok(())
}

/// `a (m,k) @ bᵀ where b is (n,k) -> (m,n)`; both operands read row-major.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, _) = mat_dims(a)?;
    let (n, _) = mat_dims(b)?;
    let mut out = Tensor::zeros(&[m, n]);
    matmul_bt_into(a, b, &mut out)?;
    Ok(out)
}

/// [`matmul_bt`] into a preallocated `(m,n)` output (fully overwritten).
pub fn matmul_bt_into(a: &Tensor, b: &Tensor, out: &mut Tensor) -> Result<()> {
    let (m, k) = mat_dims(a)?;
    let (n, k2) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul_bt inner dim mismatch: {:?} @ {:?}ᵀ", a.shape(), b.shape());
    }
    check_out_shape("matmul_bt", out, m, n)?;
    if m == 0 || n == 0 {
        return Ok(());
    }
    kernel::gemm_nt(a.data(), b.data(), m, k, n, out.data_mut());
    Ok(())
}

/// `out (m,n) += alpha · (a (m,k) @ bᵀ)` with `b` row-major (n,k) — the
/// scale-and-accumulate epilogue. What used to be `matmul_bt_into` plus an
/// `axpy` (a full output write and re-read) is one fused pass; the element
/// update `o += alpha · dot` is arithmetic-identical to the old pair under
/// the scalar kernel.
pub fn matmul_bt_scaled_add_into(
    a: &Tensor,
    b: &Tensor,
    alpha: f32,
    out: &mut Tensor,
) -> Result<()> {
    let (m, k) = mat_dims(a)?;
    let (n, k2) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul_bt_scaled_add inner dim mismatch: {:?} @ {:?}ᵀ", a.shape(), b.shape());
    }
    check_out_shape("matmul_bt_scaled_add", out, m, n)?;
    if m == 0 || n == 0 {
        return Ok(());
    }
    kernel::gemm_nt_scaled_add(a.data(), b.data(), m, k, n, alpha, out.data_mut());
    Ok(())
}

/// Scatter variant of [`matmul_bt_scaled_add_into`]:
/// `out[dst[r]] += scales[r] · (a_r @ bᵀ)` for each row `r` of `a`. The
/// merged-expert recombination of `moe_forward_ws` runs on this — the
/// per-expert output batch is never materialized. `dst` must be strictly
/// increasing (gathered token indices are) so destination rows are provably
/// distinct and the row fan-out is race-free; rows of `out` not named in
/// `dst` are left untouched.
pub fn matmul_bt_scatter_add_into(
    a: &Tensor,
    b: &Tensor,
    scales: &[f32],
    dst: &[usize],
    out: &mut Tensor,
) -> Result<()> {
    let (m, k) = mat_dims(a)?;
    let (n, k2) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul_bt_scatter_add inner dim mismatch: {:?} @ {:?}ᵀ", a.shape(), b.shape());
    }
    let (t, oc) = mat_dims(out)?;
    if oc != n {
        bail!("matmul_bt_scatter_add: output has {oc} cols, expected {n}");
    }
    if scales.len() != m || dst.len() != m {
        bail!(
            "matmul_bt_scatter_add: {m} rows need {m} scales/dst, got {}/{}",
            scales.len(),
            dst.len()
        );
    }
    if !dst.windows(2).all(|w| w[0] < w[1]) {
        bail!("matmul_bt_scatter_add: dst must be strictly increasing");
    }
    if let Some(&last) = dst.last() {
        if last >= t {
            bail!("matmul_bt_scatter_add: dst row {last} out of bounds for {t} rows");
        }
    }
    if m == 0 || n == 0 {
        return Ok(());
    }
    // SAFETY: the checks above establish the kernel's contract — `dst` is
    // strictly increasing and its last (largest) entry indexes a full row
    // inside `out`.
    unsafe {
        kernel::gemm_nt_scatter_add(a.data(), b.data(), m, k, n, scales, dst, out.data_mut());
    }
    Ok(())
}

/// Fused SwiGLU panel: `out (m,f) = silu(x @ wgᵀ) ⊙ (x @ wuᵀ)` with
/// `wg`/`wu` row-major (f,d). One pass over each `x` row feeds both dot
/// products; under the scalar kernel the result is bit-identical to the
/// historical two-GEMM + elementwise path.
pub fn swiglu_bt_into(x: &Tensor, wg: &Tensor, wu: &Tensor, out: &mut Tensor) -> Result<()> {
    let (m, k) = mat_dims(x)?;
    let (f, k2) = mat_dims(wg)?;
    if k != k2 {
        bail!("swiglu_bt inner dim mismatch: {:?} @ {:?}ᵀ", x.shape(), wg.shape());
    }
    if wu.shape() != wg.shape() {
        bail!("swiglu_bt gate/up shape mismatch: {:?} vs {:?}", wg.shape(), wu.shape());
    }
    check_out_shape("swiglu_bt", out, m, f)?;
    if m == 0 || f == 0 {
        return Ok(());
    }
    kernel::gemm_nt_swiglu(x.data(), wg.data(), wu.data(), m, k, f, out.data_mut());
    Ok(())
}

/// Symmetric rank-k update `p (f,s) @ pᵀ -> (f,f)` — the MergeMoE Gram
/// block `P Pᵀ`. Computes the lower triangle and mirrors it; because column
/// dots are grouping-invariant in every kernel family, the result equals
/// `matmul_bt(p, p)` exactly at half the flops.
pub fn syrk_bt(p: &Tensor) -> Result<Tensor> {
    let (f, _) = mat_dims(p)?;
    let mut out = Tensor::zeros(&[f, f]);
    syrk_bt_into(p, &mut out)?;
    Ok(out)
}

/// [`syrk_bt`] into a preallocated `(f,f)` output (fully overwritten).
pub fn syrk_bt_into(p: &Tensor, out: &mut Tensor) -> Result<()> {
    let (f, s) = mat_dims(p)?;
    check_out_shape("syrk_bt", out, f, f)?;
    if f == 0 {
        return Ok(());
    }
    kernel::syrk_nt(p.data(), f, s, out.data_mut());
    Ok(())
}

/// `aᵀ (k,m)ᵀ @ b (k,n) -> (m,n)` — the column-major accumulation form
/// (Theorem-1 quadratic forms, QᵀQ checks). Its `a` operands are the ones
/// that arrive sparse, so the zero-skip stays on this path only.
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (_, m) = mat_dims(a)?;
    let (_, n) = mat_dims(b)?;
    let mut out = Tensor::zeros(&[m, n]);
    matmul_at_into(a, b, &mut out)?;
    Ok(out)
}

/// [`matmul_at`] into a preallocated `(m,n)` output (fully overwritten).
pub fn matmul_at_into(a: &Tensor, b: &Tensor, out: &mut Tensor) -> Result<()> {
    let (k, m) = mat_dims(a)?;
    let (k2, n) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul_at inner dim mismatch: {:?}ᵀ @ {:?}", a.shape(), b.shape());
    }
    check_out_shape("matmul_at", out, m, n)?;
    if m == 0 || n == 0 {
        return Ok(());
    }
    kernel::gemm_tn(a.data(), b.data(), k, m, n, out.data_mut());
    Ok(())
}

fn mat_dims(t: &Tensor) -> Result<(usize, usize)> {
    match t.shape() {
        [m, n] => Ok((*m, *n)),
        s => bail!("expected 2-D tensor, got {s:?}"),
    }
}

fn check_out_shape(op: &str, out: &Tensor, m: usize, n: usize) -> Result<()> {
    if out.shape() != [m, n] {
        bail!("{op}_into: output shape {:?} != ({m}, {n})", out.shape());
    }
    Ok(())
}

/// 2-D transpose.
pub fn transpose(t: &Tensor) -> Result<Tensor> {
    let (m, n) = mat_dims(t)?;
    let mut out = Tensor::zeros(&[n, m]);
    transpose_into(t, &mut out)?;
    Ok(out)
}

/// [`transpose`] into a preallocated `(n,m)` output (fully overwritten).
pub fn transpose_into(t: &Tensor, out: &mut Tensor) -> Result<()> {
    let (m, n) = mat_dims(t)?;
    check_out_shape("transpose", out, n, m)?;
    if m == 0 || n == 0 {
        return Ok(());
    }
    let td = t.data();
    par::par_chunks_mut(out.data_mut(), m, |j, orow| {
        for (i, o) in orow.iter_mut().enumerate() {
            *o = td[i * n + j];
        }
    });
    Ok(())
}

/// Row-wise softmax over the last dimension (numerically stabilized).
pub fn softmax_rows(t: &Tensor) -> Tensor {
    let mut out = t.clone();
    softmax_rows_inplace(&mut out);
    out
}

/// [`softmax_rows`] applied in place — the zero-alloc routing path writes
/// logits into a workspace buffer and normalizes them where they sit.
pub fn softmax_rows_inplace(t: &mut Tensor) {
    let c = t.cols();
    if c == 0 {
        return;
    }
    par::par_chunks_mut(t.data_mut(), c, |_i, row| {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    });
}

/// Row-wise log-softmax over the last dimension.
pub fn log_softmax_rows(t: &Tensor) -> Tensor {
    let c = t.cols();
    let mut out = t.clone();
    if c == 0 {
        return out;
    }
    par::par_chunks_mut(out.data_mut(), c, |_i, row| {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
        let lz = z.ln() + m;
        for v in row.iter_mut() {
            *v -= lz;
        }
    });
    out
}

/// LayerNorm over the last dimension with affine params (eps matches the L2
/// model: 1e-5).
pub fn layernorm(t: &Tensor, gamma: &[f32], beta: &[f32]) -> Result<Tensor> {
    let mut out = t.clone();
    layernorm_rows(&mut out, gamma, beta)?;
    Ok(out)
}

/// [`layernorm`] into a caller-owned output buffer (resized to match `t`,
/// fully overwritten) — the workspace path of the forward pass.
pub fn layernorm_into(t: &Tensor, gamma: &[f32], beta: &[f32], out: &mut Tensor) -> Result<()> {
    out.reuse_like(t);
    out.data_mut().copy_from_slice(t.data());
    layernorm_rows(out, gamma, beta)
}

/// Normalize each row of `t` in place.
fn layernorm_rows(t: &mut Tensor, gamma: &[f32], beta: &[f32]) -> Result<()> {
    let c = t.cols();
    if gamma.len() != c || beta.len() != c {
        bail!("layernorm param size mismatch: {} vs {}", gamma.len(), c);
    }
    if c == 0 {
        return Ok(());
    }
    par::par_chunks_mut(t.data_mut(), c, |_i, row| {
        let mean = row.iter().sum::<f32>() / c as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gamma[j] + beta[j];
        }
    });
    Ok(())
}

/// SiLU (swish) activation, matching `jax.nn.silu`. One definition shared
/// with the fused kernel epilogues (`kernel::silu`), so fused and unfused
/// paths agree bit for bit.
#[inline]
pub fn silu(x: f32) -> f32 {
    kernel::silu(x)
}

/// Indices and values of the top-k entries of a row (descending, stable on
/// ties by lower index — matches `jax.lax.top_k`). Ordering is total
/// (`f32::total_cmp`), so NaN logits sort deterministically (NaN compares
/// greater than +inf) instead of panicking.
#[deprecated(
    note = "test-only convenience: it allocates two Vecs per call; \
            production paths use `top_k_order` with a reused buffer"
)]
pub fn top_k(row: &[f32], k: usize) -> (Vec<usize>, Vec<f32>) {
    let mut idx = Vec::new();
    top_k_order(row, k, &mut idx);
    let vals = idx.iter().map(|&i| row[i]).collect();
    (idx, vals)
}

/// [`top_k`] writing the selected indices into a reusable buffer (cleared
/// first) — the zero-alloc routing path. Same ordering contract as
/// [`top_k`]; values are read back through the returned indices.
pub fn top_k_order(row: &[f32], k: usize, order: &mut Vec<usize>) {
    order.clear();
    order.extend(0..row.len());
    // The comparator is a total order with no ties (index breaks them), so
    // the unstable sort returns exactly the stable ordering — and, unlike
    // the stable sort, never allocates a scratch buffer.
    order.sort_unstable_by(|&a, &b| row[b].total_cmp(&row[a]).then(a.cmp(&b)));
    order.truncate(k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut o = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                *o.at2_mut(i, j) = s;
            }
        }
        o
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let m = rng.range(1, 33) as usize;
            let k = rng.range(1, 90) as usize;
            let n = rng.range(1, 40) as usize;
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let got = matmul(&a, &b).unwrap();
            let want = naive_matmul(&a, &b);
            assert!(got.rel_err(&want) < 1e-5, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn matmul_bt_and_at_agree_with_transpose() {
        let mut rng = Rng::new(22);
        let a = Tensor::randn(&[17, 23], 1.0, &mut rng);
        let b = Tensor::randn(&[9, 23], 1.0, &mut rng);
        let want = matmul(&a, &transpose(&b).unwrap()).unwrap();
        let got = matmul_bt(&a, &b).unwrap();
        assert!(got.rel_err(&want) < 1e-5);

        let c = Tensor::randn(&[23, 11], 1.0, &mut rng);
        let at = Tensor::randn(&[23, 6], 1.0, &mut rng);
        let want2 = matmul(&transpose(&at).unwrap(), &c).unwrap();
        let got2 = matmul_at(&at, &c).unwrap();
        assert!(got2.rel_err(&want2) < 1e-5);
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        let mut rng = Rng::new(23);
        let a = Tensor::randn(&[13, 31], 1.0, &mut rng);
        let b = Tensor::randn(&[31, 9], 1.0, &mut rng);
        let want = matmul(&a, &b).unwrap();
        let mut out = Tensor::full(&[13, 9], f32::NAN); // dirty reuse buffer
        matmul_into(&a, &b, &mut out).unwrap();
        assert_eq!(out.data(), want.data());

        let bt = Tensor::randn(&[9, 31], 1.0, &mut rng);
        let want_bt = matmul_bt(&a, &bt).unwrap();
        let mut out_bt = Tensor::full(&[13, 9], 7.0);
        matmul_bt_into(&a, &bt, &mut out_bt).unwrap();
        assert_eq!(out_bt.data(), want_bt.data());

        let at = Tensor::randn(&[31, 5], 1.0, &mut rng);
        let c = Tensor::randn(&[31, 6], 1.0, &mut rng);
        let want_at = matmul_at(&at, &c).unwrap();
        let mut out_at = Tensor::full(&[5, 6], -3.0);
        matmul_at_into(&at, &c, &mut out_at).unwrap();
        assert_eq!(out_at.data(), want_at.data());

        // shape mismatch on the out tensor is an error, not a panic
        let mut bad = Tensor::zeros(&[2, 2]);
        assert!(matmul_into(&a, &b, &mut bad).is_err());
    }

    #[test]
    fn degenerate_shapes_are_ok() {
        let a = Tensor::zeros(&[0, 5]);
        let b = Tensor::zeros(&[5, 4]);
        assert_eq!(matmul(&a, &b).unwrap().shape(), &[0, 4]);
        let a2 = Tensor::zeros(&[3, 0]);
        let b2 = Tensor::zeros(&[0, 4]);
        let z = matmul(&a2, &b2).unwrap();
        assert_eq!(z.shape(), &[3, 4]);
        assert!(z.data().iter().all(|&v| v == 0.0));
        let bt = Tensor::zeros(&[0, 5]);
        assert_eq!(matmul_bt(&Tensor::zeros(&[2, 5]), &bt).unwrap().shape(), &[2, 0]);
        assert_eq!(transpose(&Tensor::zeros(&[0, 3])).unwrap().shape(), &[3, 0]);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 5]);
        assert!(matmul(&a, &b).is_err());
        assert!(matmul_bt(&a, &b).is_err());
        assert!(matmul(&a, &Tensor::zeros(&[3])).is_err());
    }

    #[test]
    fn swiglu_fused_matches_unfused() {
        let mut rng = Rng::new(24);
        for (t, d, f) in [(7usize, 19usize, 11usize), (1, 8, 1), (5, 1, 4)] {
            let x = Tensor::randn(&[t, d], 1.0, &mut rng);
            let wg = Tensor::randn(&[f, d], 1.0, &mut rng);
            let wu = Tensor::randn(&[f, d], 1.0, &mut rng);
            let g = matmul_bt(&x, &wg).unwrap();
            let u = matmul_bt(&x, &wu).unwrap();
            let mut fused = Tensor::full(&[t, f], f32::NAN);
            swiglu_bt_into(&x, &wg, &wu, &mut fused).unwrap();
            for i in 0..t {
                for j in 0..f {
                    assert_eq!(
                        fused.at2(i, j),
                        silu(g.at2(i, j)) * u.at2(i, j),
                        "t={t} d={d} f={f} ({i},{j})"
                    );
                }
            }
        }
        // gate/up shape mismatch is an error
        let x = Tensor::zeros(&[2, 4]);
        let wg = Tensor::zeros(&[3, 4]);
        let wu = Tensor::zeros(&[2, 4]);
        let mut out = Tensor::zeros(&[2, 3]);
        assert!(swiglu_bt_into(&x, &wg, &wu, &mut out).is_err());
    }

    #[test]
    fn scaled_add_matches_matmul_plus_axpy() {
        let mut rng = Rng::new(25);
        let a = Tensor::randn(&[9, 13], 1.0, &mut rng);
        let b = Tensor::randn(&[6, 13], 1.0, &mut rng);
        let mut want = Tensor::randn(&[9, 6], 1.0, &mut rng);
        let mut got = want.clone();
        let y = matmul_bt(&a, &b).unwrap();
        want.axpy(0.37, &y).unwrap();
        matmul_bt_scaled_add_into(&a, &b, 0.37, &mut got).unwrap();
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn scatter_add_matches_serial_scatter() {
        let mut rng = Rng::new(26);
        let a = Tensor::randn(&[4, 10], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 10], 1.0, &mut rng);
        let scales = [0.5f32, -1.25, 2.0, 0.125];
        let dst = [1usize, 2, 5, 6];
        let mut want = Tensor::randn(&[8, 5], 1.0, &mut rng);
        let mut got = want.clone();
        let y = matmul_bt(&a, &b).unwrap();
        for (r, (&w, &ti)) in scales.iter().zip(&dst).enumerate() {
            for (o, &v) in want.row_mut(ti).iter_mut().zip(y.row(r)) {
                *o += w * v;
            }
        }
        matmul_bt_scatter_add_into(&a, &b, &scales, &dst, &mut got).unwrap();
        assert_eq!(got.data(), want.data());

        // non-increasing dst and out-of-bounds dst are errors, not UB
        let mut out = Tensor::zeros(&[8, 5]);
        assert!(matmul_bt_scatter_add_into(&a, &b, &scales, &[2, 1, 5, 6], &mut out).is_err());
        assert!(matmul_bt_scatter_add_into(&a, &b, &scales, &[1, 2, 5, 8], &mut out).is_err());
        assert!(matmul_bt_scatter_add_into(&a, &b, &scales[..3], &dst, &mut out).is_err());
    }

    #[test]
    fn syrk_equals_full_bt_product() {
        let mut rng = Rng::new(27);
        for (f, s) in [(6usize, 40usize), (1, 3), (9, 1), (5, 5)] {
            let p = Tensor::randn(&[f, s], 1.0, &mut rng);
            let want = matmul_bt(&p, &p).unwrap();
            let got = syrk_bt(&p).unwrap();
            assert_eq!(got.data(), want.data(), "f={f} s={s}");
        }
        // degenerate: f = 0
        assert_eq!(syrk_bt(&Tensor::zeros(&[0, 4])).unwrap().shape(), &[0, 0]);
    }

    #[test]
    fn softmax_rows_normalized() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 1000., 1000., 1000.]).unwrap();
        let s = softmax_rows(&t);
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // large-value row must not produce NaN
        assert!((s.at2(1, 0) - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_consistent() {
        let t = Tensor::from_vec(&[1, 4], vec![0.1, -2.0, 3.0, 0.5]).unwrap();
        let ls = log_softmax_rows(&t);
        let s = softmax_rows(&t);
        for j in 0..4 {
            assert!((ls.at2(0, j).exp() - s.at2(0, j)).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::new(4);
        let t = Tensor::randn(&[5, 64], 3.0, &mut rng);
        let g = vec![1.0; 64];
        let b = vec![0.0; 64];
        let o = layernorm(&t, &g, &b).unwrap();
        for i in 0..5 {
            let row = o.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    #[allow(deprecated)]
    fn top_k_matches_sort() {
        let row = [0.1, 0.7, 0.3, 0.7, 0.05];
        let (idx, vals) = top_k(&row, 3);
        assert_eq!(idx, vec![1, 3, 2]); // stable tie-break by index
        assert_eq!(vals, vec![0.7, 0.7, 0.3]);
    }

    #[test]
    #[allow(deprecated)]
    fn top_k_tolerates_nan() {
        // Regression: partial_cmp().unwrap() used to panic here. total_cmp
        // orders NaN above +inf, so NaN logits win deterministically and the
        // remaining entries keep their descending stable order.
        let row = [0.5, f32::NAN, 0.9, f32::NAN, 0.1];
        let (idx, vals) = top_k(&row, 4);
        assert_eq!(idx, vec![1, 3, 2, 0]);
        assert!(vals[0].is_nan() && vals[1].is_nan());
        assert_eq!(vals[2], 0.9);
        // all-NaN rows still produce k stable indices
        let (idx2, _) = top_k(&[f32::NAN; 3], 2);
        assert_eq!(idx2, vec![0, 1]);
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}
