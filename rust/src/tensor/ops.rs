//! Tensor operations: parallel register-tiled matmul kernels plus the
//! neural-net primitives the native engine needs (softmax, layernorm, silu,
//! top-k).
//!
//! The matmul family is the native engine's hot path. All three variants are
//! parallelized over output rows through [`par::par_chunks_mut`] and use
//! register-tiled micro-kernels (4-wide unrolling with independent
//! accumulators, which LLVM turns into vector FMAs):
//!
//! * [`matmul`]    — dense i-k-j kernel, 4 `a`-values per pass over the
//!   output row. No sparsity branch: the dense path is branch-free so it
//!   vectorizes.
//! * [`matmul_bt`] — `a @ bᵀ`, 4 output columns per pass sharing one read of
//!   the `a` row (every linear layer uses the `y = x Wᵀ` convention).
//! * [`matmul_at`] — `aᵀ @ b`; keeps the zero-skip because its `a` operands
//!   (Theorem-1 usage/assignment masses, column-chunked accumulation
//!   panels) are the ones that arrive sparse. The dense routing redirect
//!   `r @ mapᵀ` goes through `matmul_bt`, whose branch-free kernel already
//!   handles top-K-sparse `r` rows at full vector speed.
//!
//! Every variant has a `*_into` twin that writes a caller-owned output
//! tensor, so steady-state serving loops can run without per-call
//! allocation. Outputs are fully overwritten — buffers need not be zeroed.
//!
//! Determinism: each output element is reduced in a fixed order that does
//! not depend on the thread count, so results are bit-identical for any
//! `MERGEMOE_THREADS` setting.

use anyhow::{bail, Result};

use super::Tensor;
use crate::util::par;

/// `a (m,k) @ b (k,n) -> (m,n)`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, _) = mat_dims(a)?;
    let (_, n) = mat_dims(b)?;
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(a, b, &mut out)?;
    Ok(out)
}

/// [`matmul`] into a preallocated `(m,n)` output (fully overwritten).
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) -> Result<()> {
    let (m, k) = mat_dims(a)?;
    let (k2, n) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul inner dim mismatch: {:?} @ {:?}", a.shape(), b.shape());
    }
    check_out_shape("matmul", out, m, n)?;
    if m == 0 || n == 0 {
        return Ok(());
    }
    let ad = a.data();
    let bd = b.data();
    let parallel = 2 * m * k * n >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, out.data_mut(), n, |i, orow| {
        matmul_row(&ad[i * k..(i + 1) * k], bd, n, orow);
    });
    Ok(())
}

/// One dense output row: `orow = arow @ b`, 4 `a` entries per sweep so the
/// inner loop is a branch-free chain of independent multiply-adds.
#[inline]
fn matmul_row(arow: &[f32], bd: &[f32], n: usize, orow: &mut [f32]) {
    orow.fill(0.0);
    let k = arow.len();
    let mut kk = 0;
    while kk + 4 <= k {
        let a0 = arow[kk];
        let a1 = arow[kk + 1];
        let a2 = arow[kk + 2];
        let a3 = arow[kk + 3];
        let b0 = &bd[kk * n..kk * n + n];
        let b1 = &bd[(kk + 1) * n..(kk + 1) * n + n];
        let b2 = &bd[(kk + 2) * n..(kk + 2) * n + n];
        let b3 = &bd[(kk + 3) * n..(kk + 3) * n + n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
        }
        kk += 4;
    }
    while kk < k {
        let av = arow[kk];
        let brow = &bd[kk * n..kk * n + n];
        for (o, &bv) in orow.iter_mut().zip(brow) {
            *o += av * bv;
        }
        kk += 1;
    }
}

/// `a (m,k) @ bᵀ where b is (n,k) -> (m,n)`; both operands read row-major.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, _) = mat_dims(a)?;
    let (n, _) = mat_dims(b)?;
    let mut out = Tensor::zeros(&[m, n]);
    matmul_bt_into(a, b, &mut out)?;
    Ok(out)
}

/// [`matmul_bt`] into a preallocated `(m,n)` output (fully overwritten).
pub fn matmul_bt_into(a: &Tensor, b: &Tensor, out: &mut Tensor) -> Result<()> {
    let (m, k) = mat_dims(a)?;
    let (n, k2) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul_bt inner dim mismatch: {:?} @ {:?}ᵀ", a.shape(), b.shape());
    }
    check_out_shape("matmul_bt", out, m, n)?;
    if m == 0 || n == 0 {
        return Ok(());
    }
    let ad = a.data();
    let bd = b.data();
    let parallel = 2 * m * k * n >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, out.data_mut(), n, |i, orow| {
        let arow = &ad[i * k..(i + 1) * k];
        // 4 output columns per pass: one read of `arow` feeds 4 independent
        // dot-product accumulators.
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &bd[j * k..j * k + k];
            let b1 = &bd[(j + 1) * k..(j + 1) * k + k];
            let b2 = &bd[(j + 2) * k..(j + 2) * k + k];
            let b3 = &bd[(j + 3) * k..(j + 3) * k + k];
            let mut s0 = 0.0f32;
            let mut s1 = 0.0f32;
            let mut s2 = 0.0f32;
            let mut s3 = 0.0f32;
            for (kk, &av) in arow.iter().enumerate() {
                s0 += av * b0[kk];
                s1 += av * b1[kk];
                s2 += av * b2[kk];
                s3 += av * b3[kk];
            }
            orow[j] = s0;
            orow[j + 1] = s1;
            orow[j + 2] = s2;
            orow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            let brow = &bd[j * k..j * k + k];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            orow[j] = acc;
            j += 1;
        }
    });
    Ok(())
}

/// `aᵀ (k,m)ᵀ @ b (k,n) -> (m,n)` — the column-major accumulation form
/// (Theorem-1 quadratic forms, QᵀQ checks). Its `a` operands are the ones
/// that arrive sparse, so the zero-skip stays on this path only.
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (_, m) = mat_dims(a)?;
    let (_, n) = mat_dims(b)?;
    let mut out = Tensor::zeros(&[m, n]);
    matmul_at_into(a, b, &mut out)?;
    Ok(out)
}

/// [`matmul_at`] into a preallocated `(m,n)` output (fully overwritten).
pub fn matmul_at_into(a: &Tensor, b: &Tensor, out: &mut Tensor) -> Result<()> {
    let (k, m) = mat_dims(a)?;
    let (k2, n) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul_at inner dim mismatch: {:?}ᵀ @ {:?}", a.shape(), b.shape());
    }
    check_out_shape("matmul_at", out, m, n)?;
    if m == 0 || n == 0 {
        return Ok(());
    }
    let ad = a.data();
    let bd = b.data();
    let parallel = 2 * m * k * n >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, out.data_mut(), n, |i, orow| {
        orow.fill(0.0);
        for kk in 0..k {
            let av = ad[kk * m + i];
            if av == 0.0 {
                continue; // routing masses are top-K sparse
            }
            let brow = &bd[kk * n..kk * n + n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    });
    Ok(())
}

fn mat_dims(t: &Tensor) -> Result<(usize, usize)> {
    match t.shape() {
        [m, n] => Ok((*m, *n)),
        s => bail!("expected 2-D tensor, got {s:?}"),
    }
}

fn check_out_shape(op: &str, out: &Tensor, m: usize, n: usize) -> Result<()> {
    if out.shape() != [m, n] {
        bail!("{op}_into: output shape {:?} != ({m}, {n})", out.shape());
    }
    Ok(())
}

/// 2-D transpose.
pub fn transpose(t: &Tensor) -> Result<Tensor> {
    let (m, n) = mat_dims(t)?;
    let mut out = Tensor::zeros(&[n, m]);
    transpose_into(t, &mut out)?;
    Ok(out)
}

/// [`transpose`] into a preallocated `(n,m)` output (fully overwritten).
pub fn transpose_into(t: &Tensor, out: &mut Tensor) -> Result<()> {
    let (m, n) = mat_dims(t)?;
    check_out_shape("transpose", out, n, m)?;
    if m == 0 || n == 0 {
        return Ok(());
    }
    let td = t.data();
    par::par_chunks_mut(out.data_mut(), m, |j, orow| {
        for (i, o) in orow.iter_mut().enumerate() {
            *o = td[i * n + j];
        }
    });
    Ok(())
}

/// Row-wise softmax over the last dimension (numerically stabilized).
pub fn softmax_rows(t: &Tensor) -> Tensor {
    let mut out = t.clone();
    softmax_rows_inplace(&mut out);
    out
}

/// [`softmax_rows`] applied in place — the zero-alloc routing path writes
/// logits into a workspace buffer and normalizes them where they sit.
pub fn softmax_rows_inplace(t: &mut Tensor) {
    let c = t.cols();
    if c == 0 {
        return;
    }
    par::par_chunks_mut(t.data_mut(), c, |_i, row| {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    });
}

/// Row-wise log-softmax over the last dimension.
pub fn log_softmax_rows(t: &Tensor) -> Tensor {
    let c = t.cols();
    let mut out = t.clone();
    if c == 0 {
        return out;
    }
    par::par_chunks_mut(out.data_mut(), c, |_i, row| {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
        let lz = z.ln() + m;
        for v in row.iter_mut() {
            *v -= lz;
        }
    });
    out
}

/// LayerNorm over the last dimension with affine params (eps matches the L2
/// model: 1e-5).
pub fn layernorm(t: &Tensor, gamma: &[f32], beta: &[f32]) -> Result<Tensor> {
    let mut out = t.clone();
    layernorm_rows(&mut out, gamma, beta)?;
    Ok(out)
}

/// [`layernorm`] into a caller-owned output buffer (resized to match `t`,
/// fully overwritten) — the workspace path of the forward pass.
pub fn layernorm_into(t: &Tensor, gamma: &[f32], beta: &[f32], out: &mut Tensor) -> Result<()> {
    out.reuse_like(t);
    out.data_mut().copy_from_slice(t.data());
    layernorm_rows(out, gamma, beta)
}

/// Normalize each row of `t` in place.
fn layernorm_rows(t: &mut Tensor, gamma: &[f32], beta: &[f32]) -> Result<()> {
    let c = t.cols();
    if gamma.len() != c || beta.len() != c {
        bail!("layernorm param size mismatch: {} vs {}", gamma.len(), c);
    }
    if c == 0 {
        return Ok(());
    }
    par::par_chunks_mut(t.data_mut(), c, |_i, row| {
        let mean = row.iter().sum::<f32>() / c as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gamma[j] + beta[j];
        }
    });
    Ok(())
}

/// SiLU (swish) activation, matching `jax.nn.silu`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Indices and values of the top-k entries of a row (descending, stable on
/// ties by lower index — matches `jax.lax.top_k`). Ordering is total
/// (`f32::total_cmp`), so NaN logits sort deterministically (NaN compares
/// greater than +inf) instead of panicking.
pub fn top_k(row: &[f32], k: usize) -> (Vec<usize>, Vec<f32>) {
    let mut idx = Vec::new();
    top_k_order(row, k, &mut idx);
    let vals = idx.iter().map(|&i| row[i]).collect();
    (idx, vals)
}

/// [`top_k`] writing the selected indices into a reusable buffer (cleared
/// first) — the zero-alloc routing path. Same ordering contract as
/// [`top_k`]; values are read back through the returned indices.
pub fn top_k_order(row: &[f32], k: usize, order: &mut Vec<usize>) {
    order.clear();
    order.extend(0..row.len());
    // The comparator is a total order with no ties (index breaks them), so
    // the unstable sort returns exactly the stable ordering — and, unlike
    // the stable sort, never allocates a scratch buffer.
    order.sort_unstable_by(|&a, &b| row[b].total_cmp(&row[a]).then(a.cmp(&b)));
    order.truncate(k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut o = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                *o.at2_mut(i, j) = s;
            }
        }
        o
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let m = rng.range(1, 33) as usize;
            let k = rng.range(1, 90) as usize;
            let n = rng.range(1, 40) as usize;
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let got = matmul(&a, &b).unwrap();
            let want = naive_matmul(&a, &b);
            assert!(got.rel_err(&want) < 1e-5, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn matmul_bt_and_at_agree_with_transpose() {
        let mut rng = Rng::new(22);
        let a = Tensor::randn(&[17, 23], 1.0, &mut rng);
        let b = Tensor::randn(&[9, 23], 1.0, &mut rng);
        let want = matmul(&a, &transpose(&b).unwrap()).unwrap();
        let got = matmul_bt(&a, &b).unwrap();
        assert!(got.rel_err(&want) < 1e-5);

        let c = Tensor::randn(&[23, 11], 1.0, &mut rng);
        let at = Tensor::randn(&[23, 6], 1.0, &mut rng);
        let want2 = matmul(&transpose(&at).unwrap(), &c).unwrap();
        let got2 = matmul_at(&at, &c).unwrap();
        assert!(got2.rel_err(&want2) < 1e-5);
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        let mut rng = Rng::new(23);
        let a = Tensor::randn(&[13, 31], 1.0, &mut rng);
        let b = Tensor::randn(&[31, 9], 1.0, &mut rng);
        let want = matmul(&a, &b).unwrap();
        let mut out = Tensor::full(&[13, 9], f32::NAN); // dirty reuse buffer
        matmul_into(&a, &b, &mut out).unwrap();
        assert_eq!(out.data(), want.data());

        let bt = Tensor::randn(&[9, 31], 1.0, &mut rng);
        let want_bt = matmul_bt(&a, &bt).unwrap();
        let mut out_bt = Tensor::full(&[13, 9], 7.0);
        matmul_bt_into(&a, &bt, &mut out_bt).unwrap();
        assert_eq!(out_bt.data(), want_bt.data());

        let at = Tensor::randn(&[31, 5], 1.0, &mut rng);
        let c = Tensor::randn(&[31, 6], 1.0, &mut rng);
        let want_at = matmul_at(&at, &c).unwrap();
        let mut out_at = Tensor::full(&[5, 6], -3.0);
        matmul_at_into(&at, &c, &mut out_at).unwrap();
        assert_eq!(out_at.data(), want_at.data());

        // shape mismatch on the out tensor is an error, not a panic
        let mut bad = Tensor::zeros(&[2, 2]);
        assert!(matmul_into(&a, &b, &mut bad).is_err());
    }

    #[test]
    fn degenerate_shapes_are_ok() {
        let a = Tensor::zeros(&[0, 5]);
        let b = Tensor::zeros(&[5, 4]);
        assert_eq!(matmul(&a, &b).unwrap().shape(), &[0, 4]);
        let a2 = Tensor::zeros(&[3, 0]);
        let b2 = Tensor::zeros(&[0, 4]);
        let z = matmul(&a2, &b2).unwrap();
        assert_eq!(z.shape(), &[3, 4]);
        assert!(z.data().iter().all(|&v| v == 0.0));
        let bt = Tensor::zeros(&[0, 5]);
        assert_eq!(matmul_bt(&Tensor::zeros(&[2, 5]), &bt).unwrap().shape(), &[2, 0]);
        assert_eq!(transpose(&Tensor::zeros(&[0, 3])).unwrap().shape(), &[3, 0]);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 5]);
        assert!(matmul(&a, &b).is_err());
        assert!(matmul_bt(&a, &b).is_err());
        assert!(matmul(&a, &Tensor::zeros(&[3])).is_err());
    }

    #[test]
    fn softmax_rows_normalized() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 1000., 1000., 1000.]).unwrap();
        let s = softmax_rows(&t);
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // large-value row must not produce NaN
        assert!((s.at2(1, 0) - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_consistent() {
        let t = Tensor::from_vec(&[1, 4], vec![0.1, -2.0, 3.0, 0.5]).unwrap();
        let ls = log_softmax_rows(&t);
        let s = softmax_rows(&t);
        for j in 0..4 {
            assert!((ls.at2(0, j).exp() - s.at2(0, j)).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::new(4);
        let t = Tensor::randn(&[5, 64], 3.0, &mut rng);
        let g = vec![1.0; 64];
        let b = vec![0.0; 64];
        let o = layernorm(&t, &g, &b).unwrap();
        for i in 0..5 {
            let row = o.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn top_k_matches_sort() {
        let row = [0.1, 0.7, 0.3, 0.7, 0.05];
        let (idx, vals) = top_k(&row, 3);
        assert_eq!(idx, vec![1, 3, 2]); // stable tie-break by index
        assert_eq!(vals, vec![0.7, 0.7, 0.3]);
    }

    #[test]
    fn top_k_tolerates_nan() {
        // Regression: partial_cmp().unwrap() used to panic here. total_cmp
        // orders NaN above +inf, so NaN logits win deterministically and the
        // remaining entries keep their descending stable order.
        let row = [0.5, f32::NAN, 0.9, f32::NAN, 0.1];
        let (idx, vals) = top_k(&row, 4);
        assert_eq!(idx, vec![1, 3, 2, 0]);
        assert!(vals[0].is_nan() && vals[1].is_nan());
        assert_eq!(vals[2], 0.9);
        // all-NaN rows still produce k stable indices
        let (idx2, _) = top_k(&[f32::NAN; 3], 2);
        assert_eq!(idx2, vec![0, 1]);
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}
