//! Tensor operations: cache-blocked matmul plus the neural-net primitives the
//! native engine needs (softmax, layernorm, silu, top-k).
//!
//! The matmul kernel is the native engine's hot path; it is written i-k-j
//! with a register-blocked inner loop over contiguous rows of `b`, which LLVM
//! auto-vectorizes. `matmul_bt` (a @ bᵀ) exists because every linear layer in
//! the model uses the `y = x Wᵀ` convention, and transposing on the fly
//! would destroy the contiguous access pattern.

use anyhow::{bail, Result};

use super::Tensor;

/// Block size for the k-dimension (fits comfortably in L1 with 64-wide rows).
const KB: usize = 64;

/// `a (m,k) @ b (k,n) -> (m,n)`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k) = mat_dims(a)?;
    let (k2, n) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul inner dim mismatch: {:?} @ {:?}", a.shape(), b.shape());
    }
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in 0..m {
            let arow = &ad[i * k..(i + 1) * k];
            let orow = &mut od[i * n..(i + 1) * n];
            for kk in kb..kend {
                let av = arow[kk];
                if av == 0.0 {
                    continue; // routing matrices are mostly zero
                }
                let brow = &bd[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    Ok(out)
}

/// `a (m,k) @ bᵀ where b is (n,k) -> (m,n)`; both operands read row-major.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (m, k) = mat_dims(a)?;
    let (n, k2) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul_bt inner dim mismatch: {:?} @ {:?}ᵀ", a.shape(), b.shape());
    }
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut od[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o = acc;
        }
    }
    Ok(out)
}

/// `aᵀ (k,m)ᵀ @ b (k,n) -> (m,n)` — used by Gram accumulations (PPᵀ, YPᵀ
/// arrive column-chunked).
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (k, m) = mat_dims(a)?;
    let (k2, n) = mat_dims(b)?;
    if k != k2 {
        bail!("matmul_at inner dim mismatch: {:?}ᵀ @ {:?}", a.shape(), b.shape());
    }
    let mut out = Tensor::zeros(&[m, n]);
    let ad = a.data();
    let bd = b.data();
    let od = out.data_mut();
    for kk in 0..k {
        let arow = &ad[kk * m..(kk + 1) * m];
        let brow = &bd[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut od[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    Ok(out)
}

fn mat_dims(t: &Tensor) -> Result<(usize, usize)> {
    match t.shape() {
        [m, n] => Ok((*m, *n)),
        s => bail!("expected 2-D tensor, got {s:?}"),
    }
}

/// 2-D transpose.
pub fn transpose(t: &Tensor) -> Result<Tensor> {
    let (m, n) = mat_dims(t)?;
    let mut out = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            *out.at2_mut(j, i) = t.at2(i, j);
        }
    }
    Ok(out)
}

/// Row-wise softmax over the last dimension (numerically stabilized).
pub fn softmax_rows(t: &Tensor) -> Tensor {
    let c = t.cols();
    let mut out = t.clone();
    for i in 0..out.rows() {
        let row = &mut out.data_mut()[i * c..(i + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
    out
}

/// Row-wise log-softmax over the last dimension.
pub fn log_softmax_rows(t: &Tensor) -> Tensor {
    let c = t.cols();
    let mut out = t.clone();
    for i in 0..out.rows() {
        let row = &mut out.data_mut()[i * c..(i + 1) * c];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
        let lz = z.ln() + m;
        for v in row.iter_mut() {
            *v -= lz;
        }
    }
    out
}

/// LayerNorm over the last dimension with affine params (eps matches the L2
/// model: 1e-5).
pub fn layernorm(t: &Tensor, gamma: &[f32], beta: &[f32]) -> Result<Tensor> {
    let c = t.cols();
    if gamma.len() != c || beta.len() != c {
        bail!("layernorm param size mismatch: {} vs {}", gamma.len(), c);
    }
    let mut out = t.clone();
    for i in 0..out.rows() {
        let row = &mut out.data_mut()[i * c..(i + 1) * c];
        let mean = row.iter().sum::<f32>() / c as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gamma[j] + beta[j];
        }
    }
    Ok(out)
}

/// SiLU (swish) activation, matching `jax.nn.silu`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Indices and values of the top-k entries of a row (descending, stable on
/// ties by lower index — matches `jax.lax.top_k`).
pub fn top_k(row: &[f32], k: usize) -> (Vec<usize>, Vec<f32>) {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap().then(a.cmp(&b)));
    idx.truncate(k);
    let vals = idx.iter().map(|&i| row[i]).collect();
    (idx, vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut o = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                *o.at2_mut(i, j) = s;
            }
        }
        o
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let m = rng.range(1, 33) as usize;
            let k = rng.range(1, 90) as usize;
            let n = rng.range(1, 40) as usize;
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let got = matmul(&a, &b).unwrap();
            let want = naive_matmul(&a, &b);
            assert!(got.rel_err(&want) < 1e-5, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn matmul_bt_and_at_agree_with_transpose() {
        let mut rng = Rng::new(22);
        let a = Tensor::randn(&[17, 23], 1.0, &mut rng);
        let b = Tensor::randn(&[9, 23], 1.0, &mut rng);
        let want = matmul(&a, &transpose(&b).unwrap()).unwrap();
        let got = matmul_bt(&a, &b).unwrap();
        assert!(got.rel_err(&want) < 1e-5);

        let c = Tensor::randn(&[23, 11], 1.0, &mut rng);
        let at = Tensor::randn(&[23, 6], 1.0, &mut rng);
        let want2 = matmul(&transpose(&at).unwrap(), &c).unwrap();
        let got2 = matmul_at(&at, &c).unwrap();
        assert!(got2.rel_err(&want2) < 1e-5);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 5]);
        assert!(matmul(&a, &b).is_err());
        assert!(matmul_bt(&a, &b).is_err());
        assert!(matmul(&a, &Tensor::zeros(&[3])).is_err());
    }

    #[test]
    fn softmax_rows_normalized() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 1000., 1000., 1000.]).unwrap();
        let s = softmax_rows(&t);
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // large-value row must not produce NaN
        assert!((s.at2(1, 0) - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn log_softmax_consistent() {
        let t = Tensor::from_vec(&[1, 4], vec![0.1, -2.0, 3.0, 0.5]).unwrap();
        let ls = log_softmax_rows(&t);
        let s = softmax_rows(&t);
        for j in 0..4 {
            assert!((ls.at2(0, j).exp() - s.at2(0, j)).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::new(4);
        let t = Tensor::randn(&[5, 64], 3.0, &mut rng);
        let g = vec![1.0; 64];
        let b = vec![0.0; 64];
        let o = layernorm(&t, &g, &b).unwrap();
        for i in 0..5 {
            let row = o.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn top_k_matches_sort() {
        let row = [0.1, 0.7, 0.3, 0.7, 0.05];
        let (idx, vals) = top_k(&row, 3);
        assert_eq!(idx, vec![1, 3, 2]); // stable tie-break by index
        assert_eq!(vals, vec![0.7, 0.7, 0.3]);
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}
