//! Dense row-major f32 tensor substrate.
//!
//! This backs (a) the native reference engine that cross-checks the PJRT
//! path, and (b) all merge-time math (clustering distances, expert
//! evaluation on calibration samples, the Gram accumulations). It is a small
//! library by design: shapes are `Vec<usize>`, storage is a flat `Vec<f32>`,
//! and the only heavily optimized routines are the [`ops`] matmul family —
//! register-tiled micro-kernels, row-parallel across worker threads, with
//! zero-alloc `*_into` variants for steady-state serving loops.

pub mod ops;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

/// The empty tensor (0 elements, 0 dims) — the cheap placeholder workspace
/// buffers start from and `std::mem::take` leaves behind.
impl Default for Tensor {
    fn default() -> Tensor {
        Tensor { shape: Vec::new(), data: Vec::new() }
    }
}

impl Tensor {
    // ---------------- constructors ----------------

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Identity matrix (n × n).
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// I.i.d. N(0, scale²) entries — property tests & synthetic weights.
    pub fn randn(shape: &[usize], scale: f32, rng: &mut Rng) -> Tensor {
        let mut t = Tensor::zeros(shape);
        for v in &mut t.data {
            *v = rng.normal() as f32 * scale;
        }
        t
    }

    // ---------------- shape ----------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Rows of the matrix view (product of all but the last dim).
    pub fn rows(&self) -> usize {
        self.len() / self.cols().max(1)
    }

    /// Last dimension.
    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    /// Re-point this tensor at a 2-D `(m, n)` shape, growing or shrinking
    /// the storage as needed. This is the workspace-arena primitive: once a
    /// buffer has seen its steady-state size, calling `reuse2` again is
    /// allocation-free (capacity is retained; shrink is a truncate, regrow
    /// zero-fills only the delta). Contents are **unspecified** — callers
    /// must fully overwrite (all `*_into` kernels do) or `fill` explicitly.
    pub fn reuse2(&mut self, m: usize, n: usize) {
        self.data.resize(m * n, 0.0);
        self.shape.clear();
        self.shape.push(m);
        self.shape.push(n);
    }

    /// [`Tensor::reuse2`] generalized to any shape (copied from `other`).
    pub fn reuse_like(&mut self, other: &Tensor) {
        self.data.resize(other.len(), 0.0);
        self.shape.clear();
        self.shape.extend_from_slice(other.shape());
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} ({} elems) to {:?}", self.shape, self.data.len(), shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    // ---------------- access ----------------

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// 2-D indexing helper (row-major).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at2_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.ndim(), 2);
        &mut self.data[i * self.shape[1] + j]
    }

    /// Borrow row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Copy of a contiguous sub-block of rows `[lo, hi)` (2-D view).
    pub fn rows_slice(&self, lo: usize, hi: usize) -> Tensor {
        let c = self.cols();
        Tensor {
            shape: vec![hi - lo, c],
            data: self.data[lo * c..hi * c].to_vec(),
        }
    }

    // ---------------- elementwise ----------------

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    pub fn scale(self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("add shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        Ok(out)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("sub shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        Ok(out)
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!("axpy shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    pub fn hadamard(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("hadamard shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a *= b;
        }
        Ok(out)
    }

    // ---------------- reductions / norms ----------------

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative Frobenius error ‖a−b‖/(‖b‖+eps) — the metric used by all
    /// cross-engine tolerance checks.
    pub fn rel_err(&self, other: &Tensor) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num.sqrt()) / (den.sqrt() + 1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.at2(1, 2), 6.0);
        let r = t.reshape(&[3, 2]).unwrap();
        assert_eq!(r.at2(2, 1), 6.0);
        assert!(Tensor::from_vec(&[2, 2], vec![1.0]).is_err());
    }

    #[test]
    fn eye_and_rows() {
        let i = Tensor::eye(3);
        assert_eq!(i.row(1), &[0., 1., 0.]);
        let s = i.rows_slice(1, 3);
        assert_eq!(s.shape(), &[2, 3]);
        assert_eq!(s.at2(0, 1), 1.0);
    }

    #[test]
    fn elementwise() {
        let a = Tensor::from_vec(&[2], vec![1., 2.]).unwrap();
        let b = Tensor::from_vec(&[2], vec![3., 5.]).unwrap();
        assert_eq!(a.add(&b).unwrap().data(), &[4., 7.]);
        assert_eq!(b.sub(&a).unwrap().data(), &[2., 3.]);
        assert_eq!(a.hadamard(&b).unwrap().data(), &[3., 10.]);
        let mut c = a.clone();
        c.axpy(2.0, &b).unwrap();
        assert_eq!(c.data(), &[7., 12.]);
        assert!(a.add(&Tensor::zeros(&[3])).is_err());
    }

    #[test]
    fn norms() {
        let a = Tensor::from_vec(&[2], vec![3., 4.]).unwrap();
        assert!((a.frob_norm() - 5.0).abs() < 1e-12);
        let b = Tensor::from_vec(&[2], vec![3., 5.]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 1.0);
        assert!(a.rel_err(&a) < 1e-12);
    }

    #[test]
    fn reuse_resizes_without_losing_capacity() {
        let mut t = Tensor::default();
        assert_eq!(t.len(), 0);
        t.reuse2(3, 4);
        assert_eq!(t.shape(), &[3, 4]);
        assert!(t.data().iter().all(|&v| v == 0.0));
        t.data_mut().fill(7.0);
        // shrink keeps storage; regrow zero-fills only the new tail
        t.reuse2(2, 2);
        assert_eq!(t.shape(), &[2, 2]);
        t.reuse2(3, 4);
        assert_eq!(t.shape(), &[3, 4]);
        let other = Tensor::zeros(&[5]);
        t.reuse_like(&other);
        assert_eq!(t.shape(), &[5]);
        assert_eq!(t.len(), 5);
    }

    #[test]
    fn randn_distribution() {
        let mut rng = Rng::new(11);
        let t = Tensor::randn(&[100, 100], 0.5, &mut rng);
        let mean: f64 = t.data().iter().map(|&x| x as f64).sum::<f64>() / 1e4;
        let var: f64 = t.data().iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / 1e4;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 0.25).abs() < 0.02, "var {var}");
    }
}
