//! Runtime: the engine abstraction and the PJRT-backed implementation that
//! executes the AOT-compiled HLO artifacts on the request path.

pub mod engine;
#[allow(clippy::module_inception)]
pub mod pjrt;

pub use engine::{Engine, NativeEngine};
pub use pjrt::PjrtEngine;
