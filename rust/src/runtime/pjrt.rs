//! PJRT-backed engine: loads HLO-text artifacts, compiles them once on the
//! CPU PJRT client, caches the executables, and composes full model
//! forwards layer by layer — the request-path backend.
//!
//! Per-layer composition is what lets compressed and uncompressed MoE layers
//! mix freely in one model (the merged layers use the `moe_*_n{N}_m{M}_*`
//! artifact with the plan's A-matrix as the routing map, untouched layers
//! the `m{N}` one with an identity map). A `monolith_*` artifact covers the
//! uncompressed configuration as a fused-graph ablation of the per-layer
//! dispatch overhead (EXPERIMENTS.md §Perf).
//!
//! Interchange is HLO **text** — see `python/compile/aot.py` and
//! DESIGN.md §9 for why serialized protos are rejected by xla_extension
//! 0.5.1.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::config::{ArtifactSpec, Dtype, Manifest};
use crate::merge::GramBackend;
use crate::model::{ModelWeights, MoeLayer};
use crate::runtime::engine::Engine;
use crate::tensor::Tensor;

/// A compiled artifact plus its spec.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

/// The PJRT engine. Executables are compiled lazily on first use and cached
/// for the lifetime of the engine (compile time is reported via the public
/// counters).
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, Compiled>,
    /// Staged weight literals keyed by (model uid, artifact, param index) —
    /// weight uploads are paid once per model version instead of per call
    /// (§Perf optimization L3-1; invalidated via [`ModelWeights::touch`]).
    literal_cache: HashMap<(u64, String, usize), xla::Literal>,
    pub n_compiled: usize,
    pub compile_seconds: f64,
    pub n_executions: u64,
    pub n_literal_uploads: u64,
}

/// Bound on staged weight literals before stale model versions are evicted.
const LITERAL_CACHE_CAP: usize = 4096;

impl PjrtEngine {
    pub fn new(manifest: Manifest) -> Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine {
            client,
            manifest,
            cache: HashMap::new(),
            literal_cache: HashMap::new(),
            n_compiled: 0,
            compile_seconds: 0.0,
            n_executions: 0,
            n_literal_uploads: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn compiled(&mut self, name: &str) -> Result<&Compiled> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.artifact(name)?.clone();
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text for {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            self.compile_seconds += t0.elapsed().as_secs_f64();
            self.n_compiled += 1;
            crate::debuglog!("compiled {name} in {:.3}s", t0.elapsed().as_secs_f64());
            self.cache.insert(name.to_string(), Compiled { exe, spec });
        }
        Ok(&self.cache[name])
    }

    /// Pre-compile every artifact a model needs at batch bucket `b`
    /// (server warm-up path).
    pub fn warmup(&mut self, model: &ModelWeights, b: usize) -> Result<()> {
        let keys = self.model_keys(model, b);
        for k in keys {
            self.compiled(&k)?;
        }
        Ok(())
    }

    fn model_keys(&self, model: &ModelWeights, b: usize) -> Vec<String> {
        let cfg = &model.cfg;
        let mut keys = vec![
            self.manifest.embed_key(cfg, b),
            self.manifest.attn_key(cfg, b),
            self.manifest.lmhead_key(cfg, b),
        ];
        for layer in &model.layers {
            keys.push(self.moe_layer_key(model, &layer.moe, b));
        }
        keys.dedup();
        keys
    }

    fn moe_layer_key(&self, model: &ModelWeights, moe: &MoeLayer, b: usize) -> String {
        let n = moe.router.shape()[0];
        let m = moe.n_experts();
        let cfg = &model.cfg;
        format!(
            "moe_d{}_f{}_n{}_m{}_k{}_{}_b{}",
            cfg.d_model, cfg.d_ff, n, m, cfg.top_k,
            if cfg.shared_expert { "sh" } else { "ns" }, b
        )
    }

    /// Execute an artifact on f32/i32 values, in manifest parameter order.
    /// `ArgValue::Staged*` arguments are uploaded once per (model uid,
    /// artifact, position) and reused from the literal cache afterwards.
    pub fn run(&mut self, name: &str, inputs: &[ArgValue]) -> Result<Vec<Tensor>> {
        self.n_executions += 1;
        // 1. make sure the executable exists (mutable phase)
        self.compiled(name)?;
        // 2. populate cache misses for staged params (mutable phase)
        {
            let spec = &self.cache[name].spec;
            if inputs.len() != spec.params.len() {
                bail!("{name}: {} inputs, spec wants {}", inputs.len(), spec.params.len());
            }
            let mut to_insert: Vec<((u64, String, usize), xla::Literal)> = Vec::new();
            for (idx, (arg, p)) in inputs.iter().zip(&spec.params).enumerate() {
                if let Some(uid) = arg.stage_uid() {
                    let key = (uid, name.to_string(), idx);
                    if !self.literal_cache.contains_key(&key) {
                        to_insert.push((key, arg.to_literal(p, name)?));
                    }
                }
            }
            if !to_insert.is_empty() {
                self.n_literal_uploads += to_insert.len() as u64;
                if self.literal_cache.len() + to_insert.len() > LITERAL_CACHE_CAP {
                    // evict everything staged for other model versions
                    let keep = to_insert[0].0 .0;
                    self.literal_cache.retain(|k, _| k.0 == keep);
                }
                for (k, v) in to_insert {
                    self.literal_cache.insert(k, v);
                }
            }
        }
        // 3. build fresh literals + assemble references (immutable phase)
        let compiled = &self.cache[name];
        let spec = &compiled.spec;
        let mut fresh: Vec<(usize, xla::Literal)> = Vec::new();
        for (idx, (arg, p)) in inputs.iter().zip(&spec.params).enumerate() {
            if arg.stage_uid().is_none() {
                fresh.push((idx, arg.to_literal(p, name)?));
            }
        }
        let n_fresh = fresh.len() as u64;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
        let mut fresh_it = fresh.iter().peekable();
        for (idx, arg) in inputs.iter().enumerate() {
            match arg.stage_uid() {
                Some(uid) => {
                    refs.push(&self.literal_cache[&(uid, name.to_string(), idx)]);
                }
                None => {
                    let (fidx, lit) = fresh_it.next().expect("fresh literal");
                    debug_assert_eq!(*fidx, idx);
                    refs.push(lit);
                }
            }
        }
        let result = compiled
            .exe
            .execute::<&xla::Literal>(&refs)
            .with_context(|| format!("executing {name}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: got {} outputs, spec says {}", parts.len(), spec.outputs.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(&spec.outputs) {
            out.push(literal_to_tensor(&lit, &ospec.shape, ospec.dtype)?);
        }
        self.n_literal_uploads += n_fresh;
        Ok(out)
    }

    /// Full model forward via per-layer artifacts.
    /// `tokens` must already be padded to a manifest batch bucket.
    fn forward_layered(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
    ) -> Result<Tensor> {
        let cfg = &model.cfg;
        let v = self.manifest.vocab;
        if s != self.manifest.seq_len {
            bail!("seq len {s} != manifest {}", self.manifest.seq_len);
        }
        let uid = model.uid;
        // embed
        let key = self.manifest.embed_key(cfg, b);
        let mut h = self
            .run(&key, &[
                ArgValue::I32(tokens.to_vec()),
                ArgValue::staged(&model.tok_emb, uid),
                ArgValue::staged(&model.pos_emb, uid),
            ])?
            .into_iter()
            .next()
            .unwrap();
        // layers — per-layer uid offset keeps weight literals of different
        // layers distinct under the shared attn/moe artifact names
        for (li, layer) in model.layers.iter().enumerate() {
            let luid = uid.wrapping_mul(1000).wrapping_add(li as u64);
            let attn_key = self.manifest.attn_key(cfg, b);
            h = self
                .run(&attn_key, &[
                    ArgValue::F32(h),
                    ArgValue::f32s(&layer.ln1_g, luid),
                    ArgValue::f32s(&layer.ln1_b, luid),
                    ArgValue::staged(&layer.wq, luid),
                    ArgValue::staged(&layer.wk, luid),
                    ArgValue::staged(&layer.wv, luid),
                    ArgValue::staged(&layer.wo, luid),
                ])?
                .into_iter()
                .next()
                .unwrap();
            let moe_key = self.moe_layer_key(model, &layer.moe, b);
            let n = layer.moe.router.shape()[0];
            let m = layer.moe.n_experts();
            if let Some(map) = &layer.moe.map {
                if map.shape() != [m, n] {
                    bail!("routing map shape {:?} != ({m},{n})", map.shape());
                }
            } else if m != n {
                bail!("moe layer has {m} experts but {n}-way router and no map");
            }
            let amap_arg = match &layer.moe.map {
                Some(map) => ArgValue::Staged(luid, LazyF32::Owned(map.clone())),
                None => ArgValue::Staged(luid, LazyF32::Owned(Tensor::eye(n))),
            };
            let mut args = vec![
                ArgValue::F32(h),
                ArgValue::f32s(&layer.ln2_g, luid),
                ArgValue::f32s(&layer.ln2_b, luid),
                ArgValue::staged(&layer.moe.router, luid),
                amap_arg,
                ArgValue::Staged(luid, LazyF32::Stacked(&layer.moe, 0)),
                ArgValue::Staged(luid, LazyF32::Stacked(&layer.moe, 1)),
                ArgValue::Staged(luid, LazyF32::Stacked(&layer.moe, 2)),
            ];
            if let Some(sh) = &layer.moe.shared {
                args.push(ArgValue::staged(&sh.wg, luid));
                args.push(ArgValue::staged(&sh.wu, luid));
                args.push(ArgValue::staged(&sh.wd, luid));
            }
            let outs = self.run(&moe_key, &args)?;
            h = outs.into_iter().next().unwrap();
        }
        // head
        let key = self.manifest.lmhead_key(cfg, b);
        let outs = self.run(&key, &[
            ArgValue::F32(h),
            ArgValue::f32s(&model.lnf_g, uid),
            ArgValue::f32s(&model.lnf_b, uid),
            ArgValue::staged(&model.head, uid),
        ])?;
        let logits = outs.into_iter().next().unwrap(); // (b, s, v)
        logits.reshape(&[b * s, v])
    }

    /// Monolithic (single fused executable) forward for the uncompressed
    /// configuration — the per-layer-dispatch ablation.
    pub fn forward_monolith(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
    ) -> Result<Tensor> {
        let key = self.manifest.monolith_key(&model.cfg, b);
        let spec = self.manifest.artifact(&key)?.clone();
        let keys = spec
            .monolith_keys
            .as_ref()
            .context("monolith artifact without key list")?
            .clone();
        let mut args = vec![ArgValue::I32(tokens.to_vec())];
        for k in &keys {
            args.push(ArgValue::Staged(model.uid, LazyF32::MonolithKey(model, k)));
        }
        let outs = self.run(&key, &args)?;
        outs.into_iter()
            .next()
            .unwrap()
            .reshape(&[b * s, self.manifest.vocab])
    }

    /// Pad sequences up to the nearest batch bucket, run, and slice back.
    pub fn logits_bucketed(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        monolith: bool,
    ) -> Result<Tensor> {
        let bucket = self.manifest.bucket_for(b);
        let mut padded = tokens.to_vec();
        padded.resize(bucket * s, 0);
        let full = if monolith {
            self.forward_monolith(model, &padded, bucket, s)?
        } else {
            self.forward_layered(model, &padded, bucket, s)?
        };
        if bucket == b {
            return Ok(full);
        }
        Ok(full.rows_slice(0, b * s))
    }
}

impl Engine for PjrtEngine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        self.logits_bucketed(model, tokens, b, s, false)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Gram backend executing the `gram_*` artifact (the L1 pallas kernel) —
/// injected into the MergeMoE solve by the compression pipeline.
pub struct PjrtGram<'a> {
    pub engine: &'a mut PjrtEngine,
    pub model: String,
}

impl GramBackend for PjrtGram<'_> {
    fn gram(&mut self, p: &Tensor, y: &Tensor) -> Result<(Tensor, Tensor)> {
        let (f, s_cols) = (p.shape()[0], p.shape()[1]);
        let d = y.shape()[0];
        let cfg = self.engine.manifest.model(&self.model)?.clone();
        let max_bucket = *self
            .engine
            .manifest
            .gram_cols
            .last()
            .context("no gram buckets")?;
        if s_cols > max_bucket {
            // split along columns and accumulate (zero-overhead: Gram blocks
            // are additive over column chunks)
            let mid = s_cols / 2;
            let (pp1, yp1) =
                self.gram(&cols_slice(p, 0, mid)?, &cols_slice(y, 0, mid)?)?;
            let (pp2, yp2) =
                self.gram(&cols_slice(p, mid, s_cols)?, &cols_slice(y, mid, s_cols)?)?;
            return Ok((pp1.add(&pp2)?, yp1.add(&yp2)?));
        }
        // smallest bucket that fits; zero-pad extra columns (they contribute
        // nothing to either Gram block)
        let bucket = *self
            .engine
            .manifest
            .gram_cols
            .iter()
            .find(|&&g| g >= s_cols)
            .unwrap();
        let key = self.engine.manifest.gram_key(&cfg, bucket);
        let pad = |t: &Tensor, rows: usize| -> Tensor {
            let mut out = Tensor::zeros(&[rows, bucket]);
            for r in 0..rows {
                out.row_mut(r)[..s_cols].copy_from_slice(t.row(r));
            }
            out
        };
        let outs = self
            .engine
            .run(&key, &[ArgValue::F32(pad(p, f)), ArgValue::F32(pad(y, d))])?;
        let mut it = outs.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }
}

fn cols_slice(t: &Tensor, lo: usize, hi: usize) -> Result<Tensor> {
    let rows = t.shape()[0];
    let mut out = Tensor::zeros(&[rows, hi - lo]);
    for r in 0..rows {
        out.row_mut(r).copy_from_slice(&t.row(r)[lo..hi]);
    }
    Ok(out)
}

/// Lazily-materialized f32 payload for staged (weight) parameters: on a
/// literal-cache hit nothing is copied or stacked at all.
pub enum LazyF32<'a> {
    Owned(Tensor),
    Slice(&'a [f32]),
    /// Stack the layer's experts on demand: 0 = wg, 1 = wu, 2 = wd.
    Stacked(&'a MoeLayer, u8),
    /// A monolith weight by key (see `monolith_weight`).
    MonolithKey(&'a ModelWeights, &'a str),
}

impl LazyF32<'_> {
    fn materialize(&self) -> Result<std::borrow::Cow<'_, [f32]>> {
        use std::borrow::Cow;
        Ok(match self {
            LazyF32::Owned(t) => Cow::Borrowed(t.data()),
            LazyF32::Slice(s) => Cow::Borrowed(s),
            LazyF32::Stacked(moe, which) => {
                let (wg, wu, wd) = moe.stacked();
                Cow::Owned(match which {
                    0 => wg.into_vec(),
                    1 => wu.into_vec(),
                    _ => wd.into_vec(),
                })
            }
            LazyF32::MonolithKey(model, key) => {
                Cow::Owned(monolith_weight(model, key)?.into_vec())
            }
        })
    }
}

/// Argument value for an artifact call. `Staged` args carry the owning
/// model's uid and are cached as XLA literals across calls.
pub enum ArgValue<'a> {
    F32(Tensor),
    I32(Vec<i32>),
    Staged(u64, LazyF32<'a>),
}

impl<'a> ArgValue<'a> {
    pub fn f32s(v: &'a [f32], uid: u64) -> ArgValue<'a> {
        ArgValue::Staged(uid, LazyF32::Slice(v))
    }

    pub fn staged(t: &Tensor, uid: u64) -> ArgValue<'a> {
        // weight tensors are small; an owned copy on the miss path keeps
        // lifetimes simple (hit path never reaches here)
        ArgValue::Staged(uid, LazyF32::Owned(t.clone()))
    }

    fn stage_uid(&self) -> Option<u64> {
        // §Perf A/B switch: MERGEMOE_NO_STAGE=1 disables the weight-literal
        // cache so benches can measure the unoptimized upload-per-call path.
        static DISABLED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        if *DISABLED.get_or_init(|| std::env::var("MERGEMOE_NO_STAGE").is_ok()) {
            return None;
        }
        match self {
            ArgValue::Staged(uid, _) => Some(*uid),
            _ => None,
        }
    }

    fn to_literal(&self, p: &crate::config::ParamSpec, art: &str) -> Result<xla::Literal> {
        let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
        let want: usize = p.shape.iter().product();
        match (self, p.dtype) {
            (ArgValue::F32(t), Dtype::F32) => {
                if t.len() != want {
                    bail!("{art}: param {} length {} != shape {:?}",
                          p.name, t.len(), p.shape);
                }
                Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
            }
            (ArgValue::Staged(_, lazy), Dtype::F32) => {
                let data = lazy.materialize()?;
                if data.len() != want {
                    bail!("{art}: staged param {} length {} != shape {:?}",
                          p.name, data.len(), p.shape);
                }
                Ok(xla::Literal::vec1(&data).reshape(&dims)?)
            }
            (ArgValue::I32(v), Dtype::I32) => {
                if v.len() != want {
                    bail!("{art}: param {} length {} != shape {:?}",
                          p.name, v.len(), p.shape);
                }
                Ok(xla::Literal::vec1(v.as_slice()).reshape(&dims)?)
            }
            _ => bail!("{art}: dtype mismatch for param {}", p.name),
        }
    }
}

fn literal_to_tensor(lit: &xla::Literal, shape: &[usize], dtype: Dtype) -> Result<Tensor> {
    match dtype {
        Dtype::F32 => {
            let v: Vec<f32> = lit.to_vec()?;
            Tensor::from_vec(shape, v)
        }
        Dtype::I32 => {
            let v: Vec<i32> = lit.to_vec()?;
            Tensor::from_vec(shape, v.into_iter().map(|x| x as f32).collect())
        }
    }
}

fn monolith_weight(model: &ModelWeights, key: &str) -> Result<Tensor> {
    let t = |v: &[f32]| Tensor::from_vec(&[v.len()], v.to_vec()).unwrap();
    if let Some(rest) = key.strip_prefix('L') {
        let (idx, name) = rest.split_once('.').context("bad monolith key")?;
        let l = &model.layers[idx.parse::<usize>()?];
        return Ok(match name {
            "ln1_g" => t(&l.ln1_g),
            "ln1_b" => t(&l.ln1_b),
            "ln2_g" => t(&l.ln2_g),
            "ln2_b" => t(&l.ln2_b),
            "wq" => l.wq.clone(),
            "wk" => l.wk.clone(),
            "wv" => l.wv.clone(),
            "wo" => l.wo.clone(),
            "router" => l.moe.router.clone(),
            "wg" => l.moe.stacked().0,
            "wu" => l.moe.stacked().1,
            "wd" => l.moe.stacked().2,
            "swg" => l.moe.shared.as_ref().context("no shared")?.wg.clone(),
            "swu" => l.moe.shared.as_ref().context("no shared")?.wu.clone(),
            "swd" => l.moe.shared.as_ref().context("no shared")?.wd.clone(),
            _ => bail!("unknown monolith key {key}"),
        });
    }
    Ok(match key {
        "tok_emb" => model.tok_emb.clone(),
        "pos_emb" => model.pos_emb.clone(),
        "lnf_g" => t(&model.lnf_g),
        "lnf_b" => t(&model.lnf_b),
        "head" => model.head.clone(),
        _ => bail!("unknown monolith key {key}"),
    })
}
