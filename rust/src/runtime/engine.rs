//! The engine abstraction: everything downstream (scorer, server,
//! experiments) talks to a [`Engine`], so the native reference path and the
//! PJRT artifact path are interchangeable and cross-checkable.

use anyhow::Result;

use crate::model::workspace::Workspace;
use crate::model::{native, ModelWeights};
use crate::tensor::Tensor;

/// A forward-pass backend. `tokens` is a row-major (b, s) id buffer;
/// the result is logits with shape (b*s, vocab).
pub trait Engine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor>;

    /// Workspace-backed variant for steady-state serving loops: writes the
    /// logits into `out` (resized in place) and draws every intermediate
    /// from `ws`, so a warm caller allocates nothing per request. The
    /// default falls back to the allocating path — backends that own device
    /// buffers (PJRT) allocate host tensors regardless.
    fn logits_ws(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        _ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        *out = self.logits(model, tokens, b, s)?;
        Ok(())
    }

    /// An independent engine instance usable from a worker thread, if the
    /// backend supports concurrent use (mirrors
    /// [`crate::merge::GramBackend::fork`]). `Some` unlocks the parallel
    /// (model, task) cell fan-out in [`crate::eval::sweep`]; the default
    /// `None` keeps every cell on the calling thread — the PJRT engine owns
    /// non-shareable device state, so it stays serial.
    fn fork(&self) -> Option<Box<dyn Engine + Send>> {
        None
    }

    fn name(&self) -> &'static str;
}

impl Engine for Box<dyn Engine> {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        (**self).logits(model, tokens, b, s)
    }

    fn logits_ws(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        (**self).logits_ws(model, tokens, b, s, ws, out)
    }

    fn fork(&self) -> Option<Box<dyn Engine + Send>> {
        (**self).fork()
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Pure-rust reference engine (see [`crate::model::native`]).
pub struct NativeEngine;

impl Engine for NativeEngine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        native::forward(model, tokens, b, s, None)
    }

    fn logits_ws(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        native::forward_ws(model, tokens, b, s, None, ws, out)
    }

    fn fork(&self) -> Option<Box<dyn Engine + Send>> {
        // Stateless: forked instances unlock the parallel sweep fan-out.
        Some(Box::new(NativeEngine))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    #[test]
    fn native_engine_runs() {
        let m = tiny_model(4, 2, false, 70);
        let tokens: Vec<i32> = (0..2 * 64).map(|i| (i % 47) as i32).collect();
        let logits = NativeEngine.logits(&m, &tokens, 2, 64).unwrap();
        assert_eq!(logits.shape(), &[128, 47]);
    }

    #[test]
    fn native_engine_forks_boxed_forwards() {
        let forked = NativeEngine.fork();
        assert!(forked.is_some());
        let boxed: Box<dyn Engine> = Box::new(NativeEngine);
        assert!(boxed.fork().is_some(), "Box<dyn Engine> must forward fork");
    }

    #[test]
    fn ws_path_matches_allocating_path() {
        let m = tiny_model(4, 2, true, 71);
        let tokens: Vec<i32> = (0..2 * 64).map(|i| (i % 47) as i32).collect();
        let want = NativeEngine.logits(&m, &tokens, 2, 64).unwrap();
        let mut ws = Workspace::new();
        let mut got = Tensor::default();
        for round in 0..3 {
            NativeEngine.logits_ws(&m, &tokens, 2, 64, &mut ws, &mut got).unwrap();
            assert_eq!(got.data(), want.data(), "round {round}");
        }
    }
}
