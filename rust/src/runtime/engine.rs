//! The engine abstraction: everything downstream (scorer, server,
//! experiments) talks to a [`Engine`], so the native reference path and the
//! PJRT artifact path are interchangeable and cross-checkable.

use anyhow::Result;

use crate::model::{native, ModelWeights};
use crate::tensor::Tensor;

/// A forward-pass backend. `tokens` is a row-major (b, s) id buffer;
/// the result is logits with shape (b*s, vocab).
pub trait Engine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor>;

    fn name(&self) -> &'static str;
}

impl Engine for Box<dyn Engine> {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        (**self).logits(model, tokens, b, s)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Pure-rust reference engine (see [`crate::model::native`]).
pub struct NativeEngine;

impl Engine for NativeEngine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        native::forward(model, tokens, b, s, None)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    #[test]
    fn native_engine_runs() {
        let m = tiny_model(4, 2, false, 70);
        let tokens: Vec<i32> = (0..2 * 64).map(|i| (i % 47) as i32).collect();
        let logits = NativeEngine.logits(&m, &tokens, 2, 64).unwrap();
        assert_eq!(logits.shape(), &[128, 47]);
    }
}
