//! The engine abstraction: everything downstream (scorer, server,
//! experiments) talks to a [`Engine`], so the native reference path and the
//! PJRT artifact path are interchangeable and cross-checkable.

use anyhow::{bail, Result};

use crate::model::workspace::{KvScratch, Workspace};
use crate::model::{native, ModelWeights};
use crate::tensor::Tensor;

/// A forward-pass backend. `tokens` is a row-major (b, s) id buffer;
/// the result is logits with shape (b*s, vocab).
pub trait Engine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor>;

    /// Workspace-backed variant for steady-state serving loops: writes the
    /// logits into `out` (resized in place) and draws every intermediate
    /// from `ws`, so a warm caller allocates nothing per request. The
    /// default falls back to the allocating path — backends that own device
    /// buffers (PJRT) allocate host tensors regardless.
    fn logits_ws(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        _ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        *out = self.logits(model, tokens, b, s)?;
        Ok(())
    }

    /// Advance an autoregressive decode to the end of `prefix`, writing the
    /// next-token logits (1, V) of the last position into `out`. `kv` holds
    /// the cached positions: entries `0..kv.len` must correspond to
    /// `prefix[0..kv.len]` (an empty/reset cache means "start over"), and
    /// the call requires `kv.len < prefix.len()` — there must be something
    /// new to decode.
    ///
    /// The default **re-prefills**: a full forward over the prefix, keeping
    /// only the last logits row. Backends with no incremental path (PJRT
    /// runs fixed-shape compiled artifacts) stay correct through it, and
    /// its existence is what makes the KV path falsifiable — the native
    /// override must match it bit for bit at every step
    /// (`tests/decode_consistency.rs`). The fallback allocates a full
    /// logits buffer per step and costs O(prefix²) per token; `kv` is
    /// advanced for bookkeeping only.
    fn decode_step(
        &mut self,
        model: &ModelWeights,
        prefix: &[i32],
        kv: &mut KvScratch,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        let s = prefix.len();
        if kv.len >= s {
            bail!("decode_step: {} positions cached, nothing new in a {s}-token prefix", kv.len);
        }
        let mut full = Tensor::default();
        self.logits_ws(model, prefix, 1, s, ws, &mut full)?;
        let v = full.cols();
        out.reuse2(1, v);
        out.data_mut().copy_from_slice(&full.data()[(s - 1) * v..]);
        kv.len = s;
        Ok(())
    }

    /// An independent engine instance usable from a worker thread, if the
    /// backend supports concurrent use (mirrors
    /// [`crate::merge::GramBackend::fork`]). `Some` unlocks the parallel
    /// (model, task) cell fan-out in [`crate::eval::sweep`]; the default
    /// `None` keeps every cell on the calling thread — the PJRT engine owns
    /// non-shareable device state, so it stays serial.
    fn fork(&self) -> Option<Box<dyn Engine + Send>> {
        None
    }

    fn name(&self) -> &'static str;
}

impl Engine for Box<dyn Engine> {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        (**self).logits(model, tokens, b, s)
    }

    fn logits_ws(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        (**self).logits_ws(model, tokens, b, s, ws, out)
    }

    fn decode_step(
        &mut self,
        model: &ModelWeights,
        prefix: &[i32],
        kv: &mut KvScratch,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        (**self).decode_step(model, prefix, kv, ws, out)
    }

    fn fork(&self) -> Option<Box<dyn Engine + Send>> {
        (**self).fork()
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Pure-rust reference engine (see [`crate::model::native`]).
pub struct NativeEngine;

impl Engine for NativeEngine {
    fn logits(&mut self, model: &ModelWeights, tokens: &[i32], b: usize, s: usize)
        -> Result<Tensor> {
        native::forward(model, tokens, b, s, None)
    }

    fn logits_ws(
        &mut self,
        model: &ModelWeights,
        tokens: &[i32],
        b: usize,
        s: usize,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        native::forward_ws(model, tokens, b, s, None, ws, out)
    }

    fn decode_step(
        &mut self,
        model: &ModelWeights,
        prefix: &[i32],
        kv: &mut KvScratch,
        ws: &mut Workspace,
        out: &mut Tensor,
    ) -> Result<()> {
        // The KV path: catch up every uncached position one token at a time
        // (the first call walks the whole prompt, later calls run exactly
        // one step). Each step is bit-identical to the matching row of a
        // full prefill, so this agrees with the default re-prefill fallback
        // bit for bit while doing O(prefix) work per token instead of
        // O(prefix²).
        let s = prefix.len();
        if kv.len >= s {
            bail!("decode_step: {} positions cached, nothing new in a {s}-token prefix", kv.len);
        }
        while kv.len < s {
            native::decode_step_ws(model, prefix[kv.len], kv, ws, out)?;
        }
        Ok(())
    }

    fn fork(&self) -> Option<Box<dyn Engine + Send>> {
        // Stateless: forked instances unlock the parallel sweep fan-out.
        Some(Box::new(NativeEngine))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    #[test]
    fn native_engine_runs() {
        let m = tiny_model(4, 2, false, 70);
        let tokens: Vec<i32> = (0..2 * 64).map(|i| (i % 47) as i32).collect();
        let logits = NativeEngine.logits(&m, &tokens, 2, 64).unwrap();
        assert_eq!(logits.shape(), &[128, 47]);
    }

    #[test]
    fn native_engine_forks_boxed_forwards() {
        let forked = NativeEngine.fork();
        assert!(forked.is_some());
        let boxed: Box<dyn Engine> = Box::new(NativeEngine);
        assert!(boxed.fork().is_some(), "Box<dyn Engine> must forward fork");
    }

    /// Delegates the forward pass to the native engine but keeps the
    /// trait's default `decode_step` (the re-prefill fallback) — the same
    /// shape a backend without a KV path, like PJRT, gets for free.
    struct ReprefillEngine;

    impl Engine for ReprefillEngine {
        fn logits(
            &mut self,
            model: &ModelWeights,
            tokens: &[i32],
            b: usize,
            s: usize,
        ) -> Result<Tensor> {
            NativeEngine.logits(model, tokens, b, s)
        }

        fn logits_ws(
            &mut self,
            model: &ModelWeights,
            tokens: &[i32],
            b: usize,
            s: usize,
            ws: &mut Workspace,
            out: &mut Tensor,
        ) -> Result<()> {
            NativeEngine.logits_ws(model, tokens, b, s, ws, out)
        }

        fn name(&self) -> &'static str {
            "reprefill"
        }
    }

    #[test]
    fn kv_decode_matches_reprefill_fallback_bitwise() {
        let m = tiny_model(4, 2, true, 72);
        let prompt: Vec<i32> = (0..10).map(|i| (i * 3 % 47) as i32).collect();
        let mut kv_a = KvScratch::new();
        let mut kv_b = KvScratch::new();
        let mut ws_a = Workspace::new();
        let mut ws_b = Workspace::new();
        let mut out_a = Tensor::default();
        let mut out_b = Tensor::default();
        for t in 0..prompt.len() {
            NativeEngine
                .decode_step(&m, &prompt[..=t], &mut kv_a, &mut ws_a, &mut out_a)
                .unwrap();
            ReprefillEngine
                .decode_step(&m, &prompt[..=t], &mut kv_b, &mut ws_b, &mut out_b)
                .unwrap();
            assert_eq!(out_a.data(), out_b.data(), "step {t}");
            assert_eq!(kv_a.len, t + 1);
            assert_eq!(kv_b.len, t + 1, "fallback must keep the bookkeeping");
        }
        // nothing new to decode is a caller error on both paths
        assert!(NativeEngine.decode_step(&m, &prompt, &mut kv_a, &mut ws_a, &mut out_a).is_err());
        assert!(ReprefillEngine.decode_step(&m, &prompt, &mut kv_b, &mut ws_b, &mut out_b).is_err());
    }

    #[test]
    fn ws_path_matches_allocating_path() {
        let m = tiny_model(4, 2, true, 71);
        let tokens: Vec<i32> = (0..2 * 64).map(|i| (i % 47) as i32).collect();
        let want = NativeEngine.logits(&m, &tokens, 2, 64).unwrap();
        let mut ws = Workspace::new();
        let mut got = Tensor::default();
        for round in 0..3 {
            NativeEngine.logits_ws(&m, &tokens, 2, 64, &mut ws, &mut got).unwrap();
            assert_eq!(got.data(), want.data(), "round {round}");
        }
    }
}
