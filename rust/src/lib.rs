//! # mergemoe
//!
//! Production-quality reproduction of *MergeMoE: Efficient Compression of MoE
//! Models via Expert Output Merging* (Miao et al., 2025) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The crate is the **Layer-3 coordinator**: it owns the request path end to
//! end — loading AOT-compiled HLO artifacts through the PJRT C API
//! ([`runtime`]), composing models layer by layer so compressed and
//! uncompressed MoE layers can mix ([`model`], [`runtime::engine`]), running
//! the paper's compression pipeline back-to-front ([`coordinator::pipeline`],
//! [`merge`]), evaluating the seven benchmark tasks ([`eval`]), and serving
//! batched scoring requests through a dynamic batcher
//! ([`coordinator::batcher`]). Python is build-time only.
//!
//! Module map (see DESIGN.md §4 for the full system inventory):
//!
//! * [`util`]    — substrates: RNG, JSON, CLI, logging, deterministic
//!   fault injection ([`util::fault`]), and [`util::par`] —
//!   the persistent-pool data-parallelism layer every hot path runs on
//!   (offline environment, so `rand`/`serde`/`clap`/`rayon` are
//!   reimplemented here).
//! * [`kernel`]  — runtime-dispatched SIMD GEMM microkernels with fused
//!   epilogues (AVX2+FMA / NEON / seed-exact scalar) — the per-core
//!   compute substrate under every matmul.
//! * [`tensor`]  — dense f32 tensor library (kernel-dispatched matmul
//!   family with zero-alloc `*_into` variants, fused SwiGLU /
//!   scale-and-accumulate / SYRK epilogues, softmax, …).
//! * [`linalg`]  — Cholesky / QR / ridge least squares / pseudoinverse: the
//!   numerical core of the paper's `T1 = Q P†` solve (triangular solves
//!   fan out per right-hand-side column; Gram products on the SYRK
//!   kernel).
//!
//! ## Threading model
//!
//! Parallelism lives in exactly one place — [`util::par`] — and runs on a
//! **persistent worker pool**: no threads exist until the first parallel
//! region (lazy init), idle workers park on a condvar between regions, and
//! [`util::par::shutdown_pool`] joins them for orderly teardown (the next
//! region respawns lazily). A region publishes a job — a block table plus
//! an atomic cursor — and the submitting thread works alongside the pool,
//! so `threads = n` bounds the lanes touching a region even when the pool
//! holds more workers. The pool is consumed at two levels: the matmul
//! kernels split output rows across lanes, and the independent units above
//! them fan out whole work items (attention per sequence, MoE per expert
//! slot, MergeMoE per cluster and per calibration chunk, triangular solves
//! per column). Nested regions automatically degrade to serial, so the two
//! levels compose without oversubscription. One knob controls everything:
//! `--threads N` on the CLI, falling back to the `MERGEMOE_THREADS`
//! environment variable, then to the core count; `threads = 1` is exactly
//! the serial execution and never touches the pool, and kernels below a
//! work cutoff (`par::PAR_MIN_FLOPS`) stay serial so single-token latency
//! never pays even a pool dispatch. Block boundaries depend only on the
//! thread knob and reductions always run in a fixed order on the
//! coordinating thread, so results are bit-identical at every thread count
//! (`tests/par_consistency.rs` enforces this against the pool).
//!
//! ## Kernel dispatch
//!
//! Below the thread level, every GEMM runs on a runtime-selected SIMD
//! microkernel family ([`kernel`]): AVX2+FMA on x86_64 (detected via
//! `is_x86_feature_detected!`), NEON on aarch64, and a scalar family that
//! preserves the seed repo's arithmetic bit for bit. Selection happens
//! **once per process** — `MERGEMOE_KERNEL={auto,scalar,avx2,neon}`
//! overrides detection (unsupported choices degrade to scalar with a
//! warning), and the resolved name is stamped into every bench/sweep
//! report plus the serve summary. The `A @ B` driver is cache-blocked over
//! k and panel-packs B on the AVX2 path at large shapes (per-thread pack
//! scratch, high-water reuse); the `A @ Bᵀ` form every linear layer uses
//! streams both operands contiguously and needs no packing. Fused epilogues
//! remove a full intermediate write+re-read each: SwiGLU for the expert
//! FFN, scale-and-accumulate (dense and scatter) for merged-expert output
//! recombination, and the symmetric rank-k update for MergeMoE's Gram
//! panels. Determinism contract: per-element reduction order depends only
//! on shapes, so results are bit-identical across `--threads` 1/2/8 under
//! any fixed kernel (`tests/par_consistency.rs`); scalar-vs-SIMD agreement
//! is a tolerance contract pinned by `tests/kernel_consistency.rs`, and
//! `MERGEMOE_KERNEL=scalar` reproduces the pre-kernel-layer numerics
//! exactly.
//!
//! ## Workspace arenas
//!
//! The inference stack threads a [`model::workspace::Workspace`] scratch
//! arena through every stage (`forward_ws`, `moe_forward_ws`,
//! `Engine::logits_ws`, the MergeMoE Gram panels), so a serving loop that
//! holds one workspace runs with **zero heap allocations at steady state**
//! (`benches/bench_forward.rs` proves it with a counting allocator).
//! Ownership rules: one workspace per worker thread — the scoring server's
//! engine thread owns one and reuses it across batches — and never shared
//! across threads; parallel lanes receive disjoint slots
//! (`Workspace::experts`, `Workspace::panels`) instead. Thin allocating
//! wrappers (`forward`, `moe_forward`, …) keep the historical signatures
//! and are bit-identical (`tests/workspace_reuse.rs`).
//!
//! ## Evaluation sweeps
//!
//! The paper's headline claims are quality-at-ratio (Tables 1–3) and
//! calibration-source robustness (Table 4), so the repo reproduces both in
//! one command: `mergemoe sweep` (backed by [`eval::sweep::run_sweep`])
//! evaluates the whole {calibration source × method × ratio × task} grid —
//! e.g.
//!
//! ```text
//! mergemoe sweep --model beta --methods average,msmoe,mergemoe --ms 6,8 \
//!                --calib-sources mixture,copy,parity --items 100
//! ```
//!
//! tokenizes each task once, captures calibration activations once per
//! source, and runs a **two-stage pipeline** over the variant stream
//! ([`util::par::pipeline`], a bounded-handoff primitive): one pinned lane
//! compresses variant `k+1` while the remaining lanes score variant `k` —
//! one forked engine + one `EvalScratch` per lane (workspaces are never
//! shared across threads), with the scorer on the zero-alloc
//! `Engine::logits_ws` path. `--threads 1` (and any non-forking engine) is
//! the exact serial execution; results are bit-identical at every thread
//! count (`tests/eval_consistency.rs`) and land as per-source
//! accuracy-vs-ratio markdown tables plus machine-readable
//! `SWEEP_<model>.json` under `artifacts/reports/`. See `ARCHITECTURE.md`
//! at the repo root for the full determinism contract — what is
//! bit-identical vs tolerance-bound, and which test pins each guarantee.
//! * [`io`]      — NPY/NPZ interchange with the build-time trainer.
//! * [`config`]  — artifact manifest + model configurations.
//! * [`model`]   — weights and the native reference forward engine.
//! * [`moe`]     — routing and usage-frequency statistics (Theorem 1 inputs).
//! * [`merge`]   — the contribution: MergeMoE + M-SMoE / Average / ZipIt
//!   baselines and the Table-5 output-merge oracle.
//! * [`calib`]   — calibration sample capture.
//! * [`eval`]    — the seven synthetic multiple-choice tasks, the
//!   workspace-backed scorer, and the `eval::sweep` comparison grid.
//! * [`runtime`] — PJRT client wrapper, executable cache, shape buckets.
//! * [`coordinator`] — batcher, overload-hardened scoring server (bounded
//!   admission, deadlines, retry/split/respawn, graceful drain), the
//!   dependency-free HTTP front end, compression pipeline, metrics.
//! * [`bench`]   — criterion-style benchmark harness (criterion unavailable).
//! * [`exp`]     — drivers that regenerate every table and figure.

pub mod bench;
pub mod calib;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod exp;
pub mod io;
pub mod kernel;
pub mod linalg;
pub mod merge;
pub mod model;
pub mod moe;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate-wide result alias (anyhow is the only error substrate available
/// offline; library APIs attach context at every fallible boundary).
pub type Result<T> = anyhow::Result<T>;
