//! # mergemoe
//!
//! Production-quality reproduction of *MergeMoE: Efficient Compression of MoE
//! Models via Expert Output Merging* (Miao et al., 2025) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The crate is the **Layer-3 coordinator**: it owns the request path end to
//! end — loading AOT-compiled HLO artifacts through the PJRT C API
//! ([`runtime`]), composing models layer by layer so compressed and
//! uncompressed MoE layers can mix ([`model`], [`runtime::engine`]), running
//! the paper's compression pipeline back-to-front ([`coordinator::pipeline`],
//! [`merge`]), evaluating the seven benchmark tasks ([`eval`]), and serving
//! batched scoring requests through a dynamic batcher
//! ([`coordinator::batcher`]). Python is build-time only.
//!
//! Module map (see DESIGN.md §4 for the full system inventory):
//!
//! * [`util`]    — substrates: RNG, JSON, CLI, logging, and [`util::par`] —
//!   the persistent-pool data-parallelism layer every hot path runs on
//!   (offline environment, so `rand`/`serde`/`clap`/`rayon` are
//!   reimplemented here).
//! * [`tensor`]  — dense f32 tensor library (parallel register-tiled
//!   matmul with zero-alloc `*_into` variants, softmax, …).
//! * [`linalg`]  — Cholesky / QR / ridge least squares / pseudoinverse: the
//!   numerical core of the paper's `T1 = Q P†` solve (triangular solves
//!   fan out per right-hand-side column).
//!
//! ## Threading model
//!
//! Parallelism lives in exactly one place — [`util::par`] — and runs on a
//! **persistent worker pool**: no threads exist until the first parallel
//! region (lazy init), idle workers park on a condvar between regions, and
//! [`util::par::shutdown_pool`] joins them for orderly teardown (the next
//! region respawns lazily). A region publishes a job — a block table plus
//! an atomic cursor — and the submitting thread works alongside the pool,
//! so `threads = n` bounds the lanes touching a region even when the pool
//! holds more workers. The pool is consumed at two levels: the matmul
//! kernels split output rows across lanes, and the independent units above
//! them fan out whole work items (attention per sequence, MoE per expert
//! slot, MergeMoE per cluster and per calibration chunk, triangular solves
//! per column). Nested regions automatically degrade to serial, so the two
//! levels compose without oversubscription. One knob controls everything:
//! `--threads N` on the CLI, falling back to the `MERGEMOE_THREADS`
//! environment variable, then to the core count; `threads = 1` is exactly
//! the serial execution and never touches the pool, and kernels below a
//! work cutoff (`par::PAR_MIN_FLOPS`) stay serial so single-token latency
//! never pays even a pool dispatch. Block boundaries depend only on the
//! thread knob and reductions always run in a fixed order on the
//! coordinating thread, so results are bit-identical at every thread count
//! (`tests/par_consistency.rs` enforces this against the pool).
//!
//! ## Workspace arenas
//!
//! The inference stack threads a [`model::workspace::Workspace`] scratch
//! arena through every stage (`forward_ws`, `moe_forward_ws`,
//! `Engine::logits_ws`, the MergeMoE Gram panels), so a serving loop that
//! holds one workspace runs with **zero heap allocations at steady state**
//! (`benches/bench_forward.rs` proves it with a counting allocator).
//! Ownership rules: one workspace per worker thread — the scoring server's
//! engine thread owns one and reuses it across batches — and never shared
//! across threads; parallel lanes receive disjoint slots
//! (`Workspace::experts`, `Workspace::panels`) instead. Thin allocating
//! wrappers (`forward`, `moe_forward`, …) keep the historical signatures
//! and are bit-identical (`tests/workspace_reuse.rs`).
//!
//! ## Evaluation sweeps
//!
//! The paper's headline claim is quality-at-ratio, so the repo reproduces
//! its comparison tables in one command: `mergemoe sweep` (backed by
//! [`eval::sweep::run_sweep`]) evaluates the whole
//! {method × ratio × task} grid — e.g.
//!
//! ```text
//! mergemoe sweep --model beta --methods average,msmoe,mergemoe --ms 6,8 \
//!                --tasks copy,parity,markov --items 100
//! ```
//!
//! tokenizes each task once, captures calibration activations once,
//! compresses once per (method, ratio) via the pipeline, then fans the
//! independent (model, task) cells across the worker pool — one forked
//! engine + one `EvalScratch` per lane (workspaces are never shared across
//! threads), with the scorer on the zero-alloc `Engine::logits_ws` path.
//! Results are bit-identical at every thread count
//! (`tests/eval_consistency.rs`) and land as an accuracy-vs-ratio markdown
//! table plus machine-readable `SWEEP_<model>.json` under
//! `artifacts/reports/`.
//! * [`io`]      — NPY/NPZ interchange with the build-time trainer.
//! * [`config`]  — artifact manifest + model configurations.
//! * [`model`]   — weights and the native reference forward engine.
//! * [`moe`]     — routing and usage-frequency statistics (Theorem 1 inputs).
//! * [`merge`]   — the contribution: MergeMoE + M-SMoE / Average / ZipIt
//!   baselines and the Table-5 output-merge oracle.
//! * [`calib`]   — calibration sample capture.
//! * [`eval`]    — the seven synthetic multiple-choice tasks, the
//!   workspace-backed scorer, and the `eval::sweep` comparison grid.
//! * [`runtime`] — PJRT client wrapper, executable cache, shape buckets.
//! * [`coordinator`] — batcher, scoring server, compression pipeline, metrics.
//! * [`bench`]   — criterion-style benchmark harness (criterion unavailable).
//! * [`exp`]     — drivers that regenerate every table and figure.

pub mod bench;
pub mod calib;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod exp;
pub mod io;
pub mod linalg;
pub mod merge;
pub mod model;
pub mod moe;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate-wide result alias (anyhow is the only error substrate available
/// offline; library APIs attach context at every fallible boundary).
pub type Result<T> = anyhow::Result<T>;
