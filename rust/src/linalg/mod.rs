//! Numerical linear algebra substrate: Cholesky, Householder QR, ridge least
//! squares and Moore–Penrose pseudoinverse.
//!
//! This is the mathematical core of the paper's method: MergeMoE's merged
//! down-projection is the least-squares solution `W_D' = Ŷ P†` (§4, Eq. 6),
//! which we compute through the normal equations `(P Pᵀ + λI) X = (Ŷ Pᵀ)ᵀ`
//! with a Cholesky solve (fast path, λ = ridge jitter for rank-deficient
//! calibration batches) and through Householder QR as the reference path the
//! property tests cross-check against.
//!
//! Kernel-layer integration: the Gram products run on the symmetric
//! rank-k kernel (`ops::syrk_bt` — lower triangle + mirror, half the
//! flops), and the *forward* substitution's dominant inner product (rows
//! of L are contiguous) runs on the dispatched mixed-precision dot
//! (`kernel::dot_f64` — 4-lane f64 FMA on AVX2). Back substitution reads L
//! down a column (stride n), so it stays on the seed scalar recurrence.
//! The per-column recurrence order is fixed per process, so the
//! fused-vs-chained solve bit contract and thread-count invariance both
//! survive kernel selection.

use anyhow::{bail, Result};

use crate::kernel;
use crate::tensor::{ops, Tensor};
use crate::util::par;

/// Cholesky factorization `A = L Lᵀ` of a symmetric positive-definite matrix.
/// Returns the lower-triangular factor. Errors if a pivot is non-positive
/// (caller should add ridge jitter and retry).
pub fn cholesky(a: &Tensor) -> Result<Tensor> {
    let n = square_dim(a)?;
    let mut l = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at2(i, j) as f64;
            for k in 0..j {
                s -= l.at2(i, k) as f64 * l.at2(j, k) as f64;
            }
            if i == j {
                if s <= 0.0 {
                    bail!("cholesky: non-positive pivot {s:.3e} at {i}");
                }
                *l.at2_mut(i, j) = (s.sqrt()) as f32;
            } else {
                *l.at2_mut(i, j) = (s / l.at2(j, j) as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// One column of `L y = b` (forward substitution), in place. `ld` is the
/// row-major n×n lower factor. Shared by every triangular solve so the
/// f64 recurrence exists exactly once (the bit-identity contract between
/// the chained and fused solves depends on it).
///
/// The dominant inner product `Σ_k l[i,k]·col[k]` runs on the dispatched
/// mixed-precision kernel ([`kernel::dot_f64`]): the scalar family keeps
/// the seed's interleaved subtract order; the SIMD families accumulate the
/// dot in 4-lane f64 FMA and subtract once. Both orders are fixed per
/// process, so the chained-vs-fused bit contract holds either way.
#[inline]
fn forward_subst_col(ld: &[f32], n: usize, col: &mut [f32]) {
    if kernel::active() == kernel::Kind::Scalar {
        for i in 0..n {
            let lrow = &ld[i * n..i * n + i + 1];
            let mut s = col[i] as f64;
            for k in 0..i {
                s -= lrow[k] as f64 * col[k] as f64;
            }
            col[i] = (s / lrow[i] as f64) as f32;
        }
        return;
    }
    for i in 0..n {
        let lrow = &ld[i * n..i * n + i + 1];
        let dot = kernel::dot_f64(&lrow[..i], &col[..i]);
        col[i] = ((col[i] as f64 - dot) / lrow[i] as f64) as f32;
    }
}

/// One column of `Lᵀ x = y` (back substitution), in place.
#[inline]
fn back_subst_col(ld: &[f32], n: usize, col: &mut [f32]) {
    for i in (0..n).rev() {
        let mut s = col[i] as f64;
        for k in i + 1..n {
            s -= ld[k * n + i] as f64 * col[k] as f64;
        }
        col[i] = (s / ld[i * n + i] as f64) as f32;
    }
}

/// Solve `L y = b` (lower-triangular forward substitution) for each column of
/// `b` (n × m). Columns are independent, so the solve runs one column per
/// parallel work item on a transposed (column-contiguous) panel — the per-
/// column recurrence itself is sequential.
pub fn solve_lower(l: &Tensor, b: &Tensor) -> Result<Tensor> {
    let n = square_dim(l)?;
    if b.shape()[0] != n {
        bail!("solve_lower shape mismatch");
    }
    if n == 0 || b.shape()[1] == 0 {
        return Ok(b.clone());
    }
    let ld = l.data();
    let mut yt = ops::transpose(b)?; // (m, n): row c = column c of b
    let parallel = n * n * b.shape()[1] >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, yt.data_mut(), n, |_c, col| {
        forward_subst_col(ld, n, col);
    });
    ops::transpose(&yt)
}

/// Solve `Lᵀ x = y` (upper-triangular back substitution), one column per
/// parallel work item (same transposed-panel layout as [`solve_lower`]).
pub fn solve_upper_t(l: &Tensor, y: &Tensor) -> Result<Tensor> {
    let n = square_dim(l)?;
    if y.shape()[0] != n {
        bail!("solve_upper_t shape mismatch");
    }
    if n == 0 || y.shape()[1] == 0 {
        return Ok(y.clone());
    }
    let ld = l.data();
    let mut xt = ops::transpose(y)?;
    let parallel = n * n * y.shape()[1] >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, xt.data_mut(), n, |_c, col| {
        back_subst_col(ld, n, col);
    });
    ops::transpose(&xt)
}

/// Solve the SPD system `A X = B` via Cholesky with escalating ridge jitter.
/// This is the production path of the MergeMoE solve: calibration Gram
/// matrices are often near-singular when the sample count is close to (or
/// below!) the hidden width — exactly the paper's Fig. 4 regime.
pub fn solve_spd(a: &Tensor, b: &Tensor, ridge: f64) -> Result<Tensor> {
    let n = square_dim(a)?;
    // Scale-invariant jitter: relative to the mean diagonal magnitude.
    let diag_scale: f64 = (0..n).map(|i| a.at2(i, i).abs() as f64).sum::<f64>() / n as f64;
    let mut jitter = ridge * diag_scale.max(1e-30);
    for _attempt in 0..8 {
        let mut aj = a.clone();
        for i in 0..n {
            *aj.at2_mut(i, i) += jitter as f32;
        }
        match cholesky(&aj) {
            Ok(l) => return solve_chol(&l, b),
            Err(_) => jitter = (jitter * 100.0).max(1e-12 * diag_scale.max(1e-30)),
        }
    }
    bail!("solve_spd: matrix not PD even with jitter (n={n})")
}

/// Solve `L Lᵀ X = B` given the Cholesky factor. One transposed
/// (column-contiguous) panel carries each right-hand-side column through
/// *both* triangular substitutions — the chained
/// [`solve_lower`]/[`solve_upper_t`] would materialize (and transpose) the
/// intermediate `Y` twice; this fused path runs one parallel region over
/// columns instead of two and allocates half the intermediates. Per-column
/// arithmetic is identical, so results match the chained solves bit for bit.
fn solve_chol(l: &Tensor, b: &Tensor) -> Result<Tensor> {
    let n = square_dim(l)?;
    if b.shape()[0] != n {
        bail!("solve_chol shape mismatch");
    }
    if n == 0 || b.shape()[1] == 0 {
        return Ok(b.clone());
    }
    let ld = l.data();
    let mut panel = ops::transpose(b)?; // (m, n): row c = column c of b
    let parallel = n * n * b.shape()[1] >= par::PAR_MIN_FLOPS;
    par::par_chunks_mut_if(parallel, panel.data_mut(), n, |_c, col| {
        forward_subst_col(ld, n, col);
        back_subst_col(ld, n, col);
    });
    ops::transpose(&panel)
}

/// Householder QR of `a` (m × n, m ≥ n): returns (Q (m,n) thin, R (n,n)).
pub fn qr(a: &Tensor) -> Result<(Tensor, Tensor)> {
    let (m, n) = match a.shape() {
        [m, n] => (*m, *n),
        s => bail!("qr expects 2-D, got {s:?}"),
    };
    if m < n {
        bail!("qr expects m >= n, got {m}x{n}");
    }
    // Work in f64 for stability.
    let mut r: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        let mut norm = 0.0;
        for i in k..m {
            norm += r[i * n + k] * r[i * n + k];
        }
        let norm = norm.sqrt();
        let mut v = vec![0.0; m];
        let akk = r[k * n + k];
        let alpha = if akk >= 0.0 { -norm } else { norm };
        if norm < 1e-300 {
            vs.push(v);
            continue;
        }
        for i in k..m {
            v[i] = r[i * n + k];
        }
        v[k] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            vs.push(vec![0.0; m]);
            continue;
        }
        // Apply H = I - 2vvᵀ/‖v‖² to R.
        for j in k..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i] * r[i * n + j];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                r[i * n + j] -= f * v[i];
            }
        }
        vs.push(v);
    }
    // Build thin Q by applying the reflectors to the first n columns of I.
    let mut q = vec![0.0f64; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i] * q[i * n + j];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= f * v[i];
            }
        }
    }
    let qt = Tensor::from_vec(&[m, n], q.iter().map(|&x| x as f32).collect())?;
    let mut rt = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in i..n {
            *rt.at2_mut(i, j) = r[i * n + j] as f32;
        }
    }
    Ok((qt, rt))
}

/// Least squares `argmin_X ‖X A - B‖_F` for row-space problems of the form
/// used by MergeMoE: `A` is (k × s) with s ≥ k samples, `B` is (d × s).
/// Solved through the normal equations `X (A Aᵀ) = B Aᵀ`.
pub fn lstsq_rows(a: &Tensor, b: &Tensor, ridge: f64) -> Result<Tensor> {
    let aat = ops::syrk_bt(a)?; // (k,k) — symmetric rank-k, half the flops
    let bat = ops::matmul_bt(b, a)?; // (d,k)
    // Solve X aat = bat  ⇔  aatᵀ Xᵀ = batᵀ; aat symmetric.
    let xt = solve_spd(&aat, &ops::transpose(&bat)?, ridge)?;
    ops::transpose(&xt)
}

/// Same solve, but starting from precomputed Gram blocks
/// `aat = A Aᵀ` and `bat = B Aᵀ` (the streaming path fed by the
/// `gram_*` PJRT artifact / pallas kernel).
pub fn lstsq_from_gram(aat: &Tensor, bat: &Tensor, ridge: f64) -> Result<Tensor> {
    let xt = solve_spd(aat, &ops::transpose(bat)?, ridge)?;
    ops::transpose(&xt)
}

/// Moore–Penrose pseudoinverse of a (k × s) matrix with s ≥ k (full-ish row
/// rank), via `A† = Aᵀ (A Aᵀ + λI)⁻¹`. Exposed mainly for tests and for the
/// literal Eq. 6 formulation; production code uses [`lstsq_rows`] which never
/// materializes `A†`.
pub fn pinv_rows(a: &Tensor, ridge: f64) -> Result<Tensor> {
    let k = a.shape()[0];
    let aat = ops::syrk_bt(a)?;
    let inv = solve_spd(&aat, &Tensor::eye(k), ridge)?;
    ops::matmul(&ops::transpose(a)?, &inv)
}

fn square_dim(a: &Tensor) -> Result<usize> {
    match a.shape() {
        [n, m] if n == m => Ok(*n),
        s => bail!("expected square matrix, got {s:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spd(n: usize, rng: &mut Rng) -> Tensor {
        let a = Tensor::randn(&[n, n], 1.0, rng);
        let mut m = ops::matmul_bt(&a, &a).unwrap();
        for i in 0..n {
            *m.at2_mut(i, i) += 0.5;
        }
        m
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(31);
        for _ in 0..10 {
            let n = rng.range(1, 24) as usize;
            let a = spd(n, &mut rng);
            let l = cholesky(&a).unwrap();
            let llt = ops::matmul_bt(&l, &l).unwrap();
            assert!(llt.rel_err(&a) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 2.0, 1.0]).unwrap();
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_solve_accuracy() {
        let mut rng = Rng::new(32);
        for _ in 0..10 {
            let n = rng.range(2, 32) as usize;
            let a = spd(n, &mut rng);
            let x_true = Tensor::randn(&[n, 3], 1.0, &mut rng);
            let b = ops::matmul(&a, &x_true).unwrap();
            let x = solve_spd(&a, &b, 0.0).unwrap();
            assert!(x.rel_err(&x_true) < 1e-3, "n={n} err={}", x.rel_err(&x_true));
        }
    }

    #[test]
    fn solve_spd_survives_singular_with_ridge() {
        // Rank-1 Gram matrix — the "too few calibration samples" regime.
        let v = Tensor::from_vec(&[3, 1], vec![1.0, 2.0, 3.0]).unwrap();
        let a = ops::matmul_bt(&v, &v).unwrap();
        let b = Tensor::eye(3);
        let x = solve_spd(&a, &b, 1e-6).unwrap();
        assert_eq!(x.shape(), &[3, 3]);
        assert!(x.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fused_solve_matches_chained_triangular_solves() {
        // solve_spd's fused panel must equal solve_lower ∘ solve_upper_t
        // bit for bit (it elides two exact transposes, nothing else).
        let mut rng = Rng::new(38);
        let a = spd(16, &mut rng);
        let b = Tensor::randn(&[16, 5], 1.0, &mut rng);
        let l = cholesky(&a).unwrap();
        let y = solve_lower(&l, &b).unwrap();
        let chained = solve_upper_t(&l, &y).unwrap();
        let fused = solve_spd(&a, &b, 0.0).unwrap();
        assert_eq!(fused.data(), chained.data());
    }

    #[test]
    fn qr_orthogonal_and_reconstructs() {
        let mut rng = Rng::new(33);
        for _ in 0..8 {
            let m = rng.range(4, 40) as usize;
            let n = rng.range(1, m as i64).max(1) as usize;
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            let (q, r) = qr(&a).unwrap();
            let qtq = ops::matmul_at(&q, &q).unwrap();
            assert!(qtq.rel_err(&Tensor::eye(n)) < 1e-4, "QᵀQ≠I m={m} n={n}");
            let qr_ = ops::matmul(&q, &r).unwrap();
            assert!(qr_.rel_err(&a) < 1e-4, "QR≠A m={m} n={n}");
        }
    }

    #[test]
    fn lstsq_recovers_planted_solution() {
        let mut rng = Rng::new(34);
        let k = 16;
        let s = 200;
        let d = 8;
        let a = Tensor::randn(&[k, s], 1.0, &mut rng);
        let x_true = Tensor::randn(&[d, k], 1.0, &mut rng);
        let b = ops::matmul(&x_true, &a).unwrap();
        let x = lstsq_rows(&a, &b, 1e-10).unwrap();
        assert!(x.rel_err(&x_true) < 1e-3, "err {}", x.rel_err(&x_true));
    }

    #[test]
    fn lstsq_is_projection_optimal() {
        // Residual of lstsq solution must not exceed residual of random
        // perturbations of it (property: least-squares optimality).
        let mut rng = Rng::new(35);
        let a = Tensor::randn(&[8, 64], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 64], 1.0, &mut rng); // not in row space
        let x = lstsq_rows(&a, &b, 1e-10).unwrap();
        let res0 = ops::matmul(&x, &a).unwrap().sub(&b).unwrap().frob_norm();
        for t in 0..10 {
            let noise = Tensor::randn(&[4, 8], 0.05, &mut Rng::new(100 + t));
            let xp = x.add(&noise).unwrap();
            let res = ops::matmul(&xp, &a).unwrap().sub(&b).unwrap().frob_norm();
            assert!(res >= res0 - 1e-6, "perturbation improved residual");
        }
    }

    #[test]
    fn lstsq_from_gram_matches_direct() {
        let mut rng = Rng::new(36);
        let a = Tensor::randn(&[12, 96], 1.0, &mut rng);
        let b = Tensor::randn(&[5, 96], 1.0, &mut rng);
        let direct = lstsq_rows(&a, &b, 1e-8).unwrap();
        let aat = ops::matmul_bt(&a, &a).unwrap();
        let bat = ops::matmul_bt(&b, &a).unwrap();
        let from_gram = lstsq_from_gram(&aat, &bat, 1e-8).unwrap();
        assert!(direct.rel_err(&from_gram) < 1e-4);
    }

    #[test]
    fn pinv_satisfies_moore_penrose_identity() {
        let mut rng = Rng::new(37);
        let a = Tensor::randn(&[6, 40], 1.0, &mut rng);
        let p = pinv_rows(&a, 1e-10).unwrap(); // (40, 6)
        // A A† A ≈ A
        let aa = ops::matmul(&ops::matmul(&a, &p).unwrap(), &a).unwrap();
        assert!(aa.rel_err(&a) < 1e-3);
    }
}
