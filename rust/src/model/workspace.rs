//! Reusable scratch arena for the inference and merge hot paths.
//!
//! PR 1 made the matmul kernels zero-alloc (`*_into` variants) but every
//! layer above them still heap-allocated its activations per call:
//! `expert_forward` its g/u panels, attention its q/k/v/context slabs, the
//! MoE layer its routing tables and per-expert batches, the MergeMoE solve
//! its Gram panels. A [`Workspace`] owns all of those buffers and is
//! threaded through `model::native::forward_ws`, `moe_forward_ws`,
//! `runtime::Engine::logits_ws` and `merge::mergemoe`, so a serving loop
//! that holds one workspace reaches a true zero-allocation steady state:
//! after warmup every buffer has its high-water size and
//! [`Tensor::reuse2`] re-points it without touching the allocator
//! (`benches/bench_forward.rs` counts allocations to prove it).
//!
//! ## Ownership rules
//!
//! * **One workspace per worker thread** — the scoring server's engine
//!   thread owns one, the calibration capture owns one, each parallel
//!   merge-cluster lane owns one. A workspace is plain `&mut` state and is
//!   **never shared across threads**; the only parallelism-aware pieces are
//!   the slot vectors ([`Workspace::experts`], [`Workspace::panels`]),
//!   whose elements are handed out one-per-lane through
//!   `par::par_chunks_mut_if` so concurrent lanes never touch the same
//!   scratch.
//! * **Contents are scratch.** No buffer's value survives a call; shapes
//!   are re-established with [`Tensor::reuse2`] at every use site. Buffers
//!   only ever grow (shrink keeps capacity), so alternating batch shapes
//!   settle at the high-water mark.
//! * **Allocating wrappers stay.** Callers that don't care about
//!   steady-state allocation keep the old signatures (`forward`,
//!   `moe_forward`, `expert_forward`, …), which spin up a throwaway
//!   workspace internally — results are bit-identical either way
//!   (`tests/workspace_reuse.rs`).

use crate::tensor::Tensor;

/// Per-layer K/V slabs for the autoregressive decode path
/// (`model::native::decode_step_ws`): one `(context, d_model)` tensor pair
/// per transformer layer, holding the keys/values of every already-decoded
/// position so a new token attends over the cached prefix instead of
/// re-running the full prefill.
///
/// Unlike every [`Workspace`] buffer, slab **contents are state, not
/// scratch**: rows `0..len` must survive across decode steps, so the slabs
/// are sized once to the model's full trained context (`pos_emb` rows) and
/// only re-pointed when the model shape changes — a warm cache never
/// touches the allocator again (the decode-loop probe in
/// `benches/bench_forward.rs` counts). Ownership follows the workspace
/// rule: one cache per decode stream, never shared across threads.
#[derive(Default)]
pub struct KvScratch {
    /// Cached keys, one `(context, d)` slab per layer; rows `0..len` valid.
    pub k: Vec<Tensor>,
    /// Cached values, same layout as `k`.
    pub v: Vec<Tensor>,
    /// Number of cached positions (the next token decodes at this position).
    pub len: usize,
}

impl KvScratch {
    pub fn new() -> KvScratch {
        KvScratch::default()
    }

    /// Forget every cached position (capacity is retained — restarting a
    /// generation on a warm cache allocates nothing).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Size the slabs for `n_layers` layers of width `d` over a `context`-
    /// position window. A no-op once the shape matches, which is what keeps
    /// warm cache rows intact: `Tensor::reuse2` contents are unspecified,
    /// so it is only called when the model shape actually changed (and the
    /// cache is emptied, since any cached rows belong to the old model).
    pub fn ensure(&mut self, n_layers: usize, context: usize, d: usize) {
        let shaped = self.k.len() == n_layers
            && self.k.iter().chain(&self.v).all(|t| t.shape() == [context, d]);
        if shaped {
            return;
        }
        self.k.resize_with(n_layers, Tensor::default);
        self.v.resize_with(n_layers, Tensor::default);
        for t in self.k.iter_mut().chain(&mut self.v) {
            t.reuse2(context, d);
        }
        self.len = 0;
    }
}

/// Per-expert (or shared-expert) scratch: the token gather, its routing
/// weights, and the fused SwiGLU activation panel. One slot per expert lane
/// so the per-expert fan-out runs without allocation. The kernel layer's
/// fused epilogues removed two buffers this struct used to carry: the
/// up-projection panel (folded into the SwiGLU kernel) and the expert
/// output batch (the down-projection scatters straight into the layer
/// output).
#[derive(Default)]
pub struct ExpertScratch {
    /// Tokens routed to this expert (indices into the layer input,
    /// strictly increasing — the scatter-GEMM contract).
    pub tok_idx: Vec<usize>,
    /// Routing weight of each gathered token (parallel to `tok_idx`).
    pub scales: Vec<f32>,
    /// Gathered input rows: (T_e, d).
    pub xs: Tensor,
    /// Fused SwiGLU activations `silu(xs W_Gᵀ) ⊙ (xs W_Uᵀ)`: (T_e, f).
    pub g: Tensor,
    /// Error raised inside a parallel lane (checked after the region).
    pub err: Option<anyhow::Error>,
}

impl ExpertScratch {
    pub fn new() -> ExpertScratch {
        ExpertScratch::default()
    }
}

/// Per-chunk scratch of the MergeMoE Gram accumulation: one slot per
/// concurrent calibration chunk (a "wave" processes at most `max_threads`
/// chunks at a time, bounding peak memory exactly as before).
#[derive(Default)]
pub struct PanelScratch {
    /// Calibration input rows of this chunk: (chunk, d).
    pub xs: Tensor,
    /// Fused SwiGLU activations of one expert on the chunk: (chunk, f).
    pub g: Tensor,
    /// Frequency-weighted member outputs, accumulated by the
    /// scale-and-add GEMM epilogue: (chunk, d).
    pub yhat: Tensor,
    /// P panel (transposed inner activations of the averaged expert): (f, chunk).
    pub p: Tensor,
    /// Ŷ panel (transposed weighted outputs): (d, chunk).
    pub y: Tensor,
    /// Error raised inside a parallel lane (checked after the region).
    pub err: Option<anyhow::Error>,
}

impl PanelScratch {
    pub fn new() -> PanelScratch {
        PanelScratch::default()
    }
}

/// Per-lane scorer scratch: the forward-pass arena plus the logits and
/// per-option score buffers of the evaluation hot path
/// (`eval::scorer::score_prepared_ws`). Ownership follows the same rule as
/// [`Workspace`]: **one scratch per sweep lane, never shared across
/// threads** — `eval::sweep` hands each pool lane exactly one of these for
/// its whole block of (model, task) cells. A warm scratch scores chunk
/// after chunk with zero heap allocations (`benches/bench_forward.rs`
/// probes this path with the counting allocator).
#[derive(Default)]
pub struct EvalScratch {
    /// Forward-pass arena ([`crate::runtime::Engine::logits_ws`] draws every
    /// intermediate from here; `ws.lps` holds the per-token log-probs).
    pub ws: Workspace,
    /// Logits of the last scored chunk: (chunk·S, V).
    pub logits: Tensor,
    /// Mean option log-probabilities of the last scored item set, two per
    /// item, option-interleaved `[item0-opt0, item0-opt1, item1-opt0, …]`.
    pub scores: Vec<f64>,
}

impl EvalScratch {
    pub fn new() -> EvalScratch {
        EvalScratch::default()
    }
}

/// The scratch arena for one worker's forward/merge hot path. All fields
/// are public by design: the forward pass borrows disjoint fields
/// simultaneously (e.g. reading `q`/`k`/`v` while writing `ctx`), which
/// only the field-level borrow checker can express.
#[derive(Default)]
pub struct Workspace {
    // ---- transformer forward pass ----
    /// Residual stream: (B·S, d).
    pub h: Tensor,
    /// Post-layernorm activations (attention input, MoE input, head input).
    pub x: Tensor,
    pub q: Tensor,
    pub k: Tensor,
    pub v: Tensor,
    /// Attention context: (B·S, d).
    pub ctx: Tensor,
    /// Per-sequence attention score rows: (B, S).
    pub scores: Tensor,
    /// Attention output projection: (B·S, d).
    pub proj: Tensor,

    // ---- MoE layer ----
    /// Router logits→probs: (T, N).
    pub route_logits: Tensor,
    /// Per-row top-k ordering scratch.
    pub route_order: Vec<usize>,
    /// Flat (expert, weight) pairs, `k` per token.
    pub route_pairs: Vec<(usize, f32)>,
    /// Dense routing weights over the N-way router: (T, N).
    pub r: Tensor,
    /// Redirected routing weights `r · mapᵀ`: (T, M).
    pub r2: Tensor,
    /// Per-expert lanes (sized to the widest layer seen).
    pub experts: Vec<ExpertScratch>,
    /// Shared-expert scratch.
    pub shared: ExpertScratch,
    /// MoE layer output: (T, d).
    pub moe_out: Tensor,
    /// Per-expert usage counts / routing-weight mass of the last MoE call.
    pub counts: Vec<f64>,
    pub mass: Vec<f64>,

    // ---- scoring ----
    /// Target log-probabilities of the last scored batch: len B·S.
    pub lps: Vec<f32>,

    // ---- merge-time Gram accumulation ----
    /// Per-chunk panel lanes for the MergeMoE solve.
    pub panels: Vec<PanelScratch>,
    /// Per-cluster sub-workspaces for the forked parallel merge path: each
    /// concurrent cluster lane owns one (never shared), and because they
    /// live in the parent workspace they are reused across layers when the
    /// compression pipeline merges several.
    pub cluster_ws: Vec<Workspace>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_starts_empty_and_grows_on_demand() {
        let mut ws = Workspace::new();
        assert_eq!(ws.h.len(), 0);
        assert!(ws.experts.is_empty());
        ws.h.reuse2(8, 16);
        assert_eq!(ws.h.shape(), &[8, 16]);
        ws.experts.resize_with(4, ExpertScratch::new);
        ws.experts[3].tok_idx.push(7);
        assert_eq!(ws.experts.len(), 4);
    }

    #[test]
    fn kv_scratch_keeps_rows_across_ensure_at_same_shape() {
        let mut kv = KvScratch::new();
        kv.ensure(2, 8, 4);
        assert_eq!(kv.k.len(), 2);
        assert_eq!(kv.k[0].shape(), &[8, 4]);
        kv.k[0].row_mut(3).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        kv.len = 4;
        // same shape: cached rows and len survive
        kv.ensure(2, 8, 4);
        assert_eq!(kv.len, 4);
        assert_eq!(kv.k[0].row(3), &[1.0, 2.0, 3.0, 4.0]);
        // reset keeps capacity, drops positions
        kv.reset();
        assert_eq!(kv.len, 0);
        assert_eq!(kv.k[0].shape(), &[8, 4]);
        // shape change re-points and empties
        kv.len = 2;
        kv.ensure(3, 8, 4);
        assert_eq!(kv.len, 0);
        assert_eq!(kv.k.len(), 3);
    }
}
