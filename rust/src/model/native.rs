//! Native (pure-rust) reference forward engine.
//!
//! Role: (a) bit-level-independent cross-check of the PJRT path — the
//! integration tests require `native ≈ pjrt ≈ python` on identical weights;
//! (b) the compute backend of merge-time math (evaluating member experts on
//! calibration samples); (c) a fallback engine so every experiment can run
//! without artifacts present.
//!
//! Numerics mirror `python/compile/model.py` exactly: pre-LN blocks,
//! softmax-then-top-K routing without renormalization, silu gating,
//! eps=1e-5 layernorm.
//!
//! Parallelism (see `util::par`): attention fans out per sequence, the MoE
//! MLP per expert batch, and the matmul kernels underneath per output row —
//! nested regions degrade to serial automatically, so the layers compose.
//! The scatter-accumulate back into the output always runs serially in
//! expert order, keeping results bit-identical at every thread count.

use anyhow::{bail, Result};

use super::{Expert, Layer, ModelWeights, MoeLayer};
use crate::moe::routing::route_tokens;
use crate::tensor::{ops, Tensor};
use crate::util::par;

/// Per-layer calibration capture (§4: the sampled inputs X̂ and the routing
/// statistics that define the frequency weights f_i).
#[derive(Debug, Clone)]
pub struct LayerCapture {
    /// Post-LN inputs to the MoE module, one row per token: (T, d).
    pub x: Tensor,
    /// Expert usage counts over these tokens: len E.
    pub counts: Vec<f64>,
    /// Sum of routing weights per expert (soft frequency): len E.
    pub weight_mass: Vec<f64>,
}

/// Apply one expert to a batch of rows: `W_D (silu(W_G x) ⊙ (W_U x))`.
pub fn expert_forward(ex: &Expert, x: &Tensor) -> Result<Tensor> {
    let h = expert_inner(ex, x)?;
    ops::matmul_bt(&h, &ex.wd)
}

/// The pre-down-projection activations `silu(W_G x) ⊙ (W_U x)` — the `Q`/`P`
/// rows of the least-squares system (transposed: returned as (T, f)).
pub fn expert_inner(ex: &Expert, x: &Tensor) -> Result<Tensor> {
    let g = ops::matmul_bt(x, &ex.wg)?;
    let u = ops::matmul_bt(x, &ex.wu)?;
    let mut h = g;
    for (hv, uv) in h.data_mut().iter_mut().zip(u.data()) {
        *hv = ops::silu(*hv) * uv;
    }
    Ok(h)
}

/// MoE MLP forward on token rows (T, d) -> (T, d), plus capture stats.
/// Implements Eq. 1 in the Appendix-B layout: the router scores the N
/// original experts; when `map` (M,N) is set the masked routing vector is
/// redirected to the M real experts (`r' = map · r`).
pub fn moe_forward(moe: &MoeLayer, x: &Tensor) -> Result<(Tensor, Vec<f64>, Vec<f64>)> {
    let t = x.shape()[0];
    let n = moe.router.shape()[0];
    let e = moe.n_experts();
    let routing = route_tokens(&moe.router, x, moe.top_k)?;
    // dense (t, n) routing weights over the N-way router
    let mut r = Tensor::zeros(&[t, n]);
    for (ti, tok) in routing.iter().enumerate() {
        for &(ei, w) in tok {
            *r.at2_mut(ti, ei) = w;
        }
    }
    if let Some(map) = &moe.map {
        r = ops::matmul_bt(&r, map)?; // (t,n) @ (m,n)ᵀ = (t,m)
    } else if e != n {
        anyhow::bail!("moe layer has {e} experts but {n}-way router and no map");
    }
    // gather tokens per expert so each expert runs one batched matmul;
    // expert batches are independent and run in parallel. Tokens may be
    // routed to several experts (top-K), so the weighted scatter back into
    // `out` stays serial, in expert order — deterministic at any thread
    // count.
    let d = x.shape()[1];
    let r_ref = &r;
    // rough per-layer MoE work: top_k experts each run 3 (f,d) matmuls per
    // routed token — skip the fan-out when the whole batch is tiny
    let f_dim = moe.experts.first().map(|ex| ex.wg.shape()[0]).unwrap_or(0);
    let parallel = 6 * t * moe.top_k * f_dim * d >= par::PAR_MIN_FLOPS;
    let per_expert: Vec<Result<Option<(Vec<usize>, Tensor)>>> = par::par_map_range_if(parallel, e, |ei| {
        let tok_idx: Vec<usize> = (0..t).filter(|&ti| r_ref.at2(ti, ei) != 0.0).collect();
        if tok_idx.is_empty() {
            return Ok(None);
        }
        let mut xs = Tensor::zeros(&[tok_idx.len(), d]);
        for (row, &ti) in tok_idx.iter().enumerate() {
            xs.row_mut(row).copy_from_slice(x.row(ti));
        }
        let ys = expert_forward(&moe.experts[ei], &xs)?;
        Ok(Some((tok_idx, ys)))
    });
    let mut counts = vec![0.0f64; e];
    let mut mass = vec![0.0f64; e];
    let mut out = Tensor::zeros(&[t, d]);
    for (ei, item) in per_expert.into_iter().enumerate() {
        let Some((tok_idx, ys)) = item? else {
            continue;
        };
        counts[ei] = tok_idx.len() as f64;
        for (row, &ti) in tok_idx.iter().enumerate() {
            let w = r.at2(ti, ei);
            mass[ei] += w as f64;
            let orow = out.row_mut(ti);
            for (o, &y) in orow.iter_mut().zip(ys.row(row)) {
                *o += w * y;
            }
        }
    }
    if let Some(sh) = &moe.shared {
        let ys = expert_forward(sh, x)?;
        out = out.add(&ys)?;
    }
    Ok((out, counts, mass))
}

/// Causal multi-head attention (pre-LN, residual) on (B, S, d).
fn attn_forward(layer: &Layer, h: &Tensor, n_heads: usize, b: usize, s: usize) -> Result<Tensor> {
    let d = h.cols();
    let hd = d / n_heads;
    let x = ops::layernorm(h, &layer.ln1_g, &layer.ln1_b)?;
    let q = ops::matmul_bt(&x, &layer.wq)?;
    let k = ops::matmul_bt(&x, &layer.wk)?;
    let v = ops::matmul_bt(&x, &layer.wv)?;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut ctx = Tensor::zeros(&[b * s, d]);
    if b * s > 0 && s > 0 {
        let qd = q.data();
        let kd = k.data();
        let vd = v.data();
        // One sequence (an s×d slab of `ctx`) per parallel work item; the
        // scores buffer is allocated once per sequence and reused across
        // every (head, query) pair — the old code allocated it per pair.
        let parallel = b * s * s * d >= par::PAR_MIN_FLOPS;
        par::par_chunks_mut_if(parallel, ctx.data_mut(), s * d, |bi, cslab| {
            let mut scores = vec![0.0f32; s];
            for head in 0..n_heads {
                let off = head * hd;
                for qi in 0..s {
                    let qbase = (bi * s + qi) * d + off;
                    let qrow = &qd[qbase..qbase + hd];
                    for ki in 0..=qi {
                        let kbase = (bi * s + ki) * d + off;
                        let krow = &kd[kbase..kbase + hd];
                        let mut dot = 0.0;
                        for (a, b2) in qrow.iter().zip(krow) {
                            dot += a * b2;
                        }
                        scores[ki] = dot * scale;
                    }
                    // softmax over the causal prefix only — entries past qi
                    // are stale scratch and never read
                    let pre = &mut scores[..=qi];
                    let m = pre.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut z = 0.0;
                    for v2 in pre.iter_mut() {
                        *v2 = (*v2 - m).exp();
                        z += *v2;
                    }
                    let orow = &mut cslab[qi * d + off..qi * d + off + hd];
                    for ki in 0..=qi {
                        let w = pre[ki] / z;
                        if w == 0.0 {
                            continue;
                        }
                        let vbase = (bi * s + ki) * d + off;
                        let vrow = &vd[vbase..vbase + hd];
                        for (o, &vv) in orow.iter_mut().zip(vrow) {
                            *o += w * vv;
                        }
                    }
                }
            }
        });
    }
    let proj = ops::matmul_bt(&ctx, &layer.wo)?;
    h.add(&proj)
}

/// Full forward pass. `tokens` is (B, S) of vocab ids; returns logits
/// (B*S, V) and, if `capture` is set, per-layer calibration records.
pub fn forward(
    model: &ModelWeights,
    tokens: &[i32],
    b: usize,
    s: usize,
    mut capture: Option<&mut Vec<LayerCapture>>,
) -> Result<Tensor> {
    if tokens.len() != b * s {
        bail!("token buffer {} != {b}x{s}", tokens.len());
    }
    let d = model.cfg.d_model;
    // embed (row-parallel: token rows are independent)
    let mut h = Tensor::zeros(&[b * s, d]);
    if d > 0 {
        par::par_chunks_mut(h.data_mut(), d, |i, row| {
            let tk = tokens[i] as usize;
            let pos = i % s;
            for (j, o) in row.iter_mut().enumerate() {
                *o = model.tok_emb.at2(tk, j) + model.pos_emb.at2(pos, j);
            }
        });
    }
    // layers
    for layer in &model.layers {
        h = attn_forward(layer, &h, model.cfg.n_heads, b, s)?;
        let x = ops::layernorm(&h, &layer.ln2_g, &layer.ln2_b)?;
        let (y, counts, mass) = moe_forward(&layer.moe, &x)?;
        if let Some(cap) = capture.as_deref_mut() {
            cap.push(LayerCapture { x: x.clone(), counts, weight_mass: mass });
        }
        h = h.add(&y)?;
    }
    // head
    let x = ops::layernorm(&h, &model.lnf_g, &model.lnf_b)?;
    ops::matmul_bt(&x, &model.head)
}

/// Log-probabilities of `targets[i]` under a causal LM: `logits` (B*S, V)
/// row i predicts token i+1 of the same sequence.
pub fn target_logprobs(logits: &Tensor, tokens: &[i32], b: usize, s: usize) -> Vec<f32> {
    let lp = ops::log_softmax_rows(logits);
    let mut out = vec![0.0f32; b * s];
    for bi in 0..b {
        for si in 0..s - 1 {
            let row = bi * s + si;
            out[row] = lp.at2(row, tokens[bi * s + si + 1] as usize);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = tiny_model(4, 2, true, 3);
        let tokens: Vec<i32> = (0..2 * 64).map(|i| (i % 47) as i32).collect();
        let logits = forward(&m, &tokens, 2, 64, None).unwrap();
        assert_eq!(logits.shape(), &[128, 47]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn capture_collects_all_layers() {
        let m = tiny_model(4, 2, false, 4);
        let tokens: Vec<i32> = (0..64).map(|i| (i % 47) as i32).collect();
        let mut cap = Vec::new();
        forward(&m, &tokens, 1, 64, Some(&mut cap)).unwrap();
        assert_eq!(cap.len(), 2);
        assert_eq!(cap[0].x.shape(), &[64, 16]);
        // top-2 of 4 experts over 64 tokens: total count = 128
        let total: f64 = cap[0].counts.iter().sum();
        assert_eq!(total, 128.0);
        assert!(cap[0].weight_mass.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn moe_forward_is_topk_sparse_mixture() {
        let m = tiny_model(8, 2, false, 5);
        let moe = &m.layers[0].moe;
        let x = Tensor::randn(&[10, 16], 1.0, &mut crate::util::rng::Rng::new(6));
        let (y, counts, _) = moe_forward(moe, &x).unwrap();
        assert_eq!(y.shape(), &[10, 16]);
        assert_eq!(counts.iter().sum::<f64>(), 20.0);
        // manual recomputation for token 0
        let routing = route_tokens(&moe.router, &x, 2).unwrap();
        let x0 = x.rows_slice(0, 1);
        let mut want = Tensor::zeros(&[1, 16]);
        for &(ei, w) in &routing[0] {
            let e_out = expert_forward(&moe.experts[ei], &x0).unwrap();
            want.axpy(w, &e_out).unwrap();
        }
        let got = y.rows_slice(0, 1);
        assert!(got.rel_err(&want) < 1e-5);
    }

    #[test]
    fn identity_map_is_noop() {
        let m = tiny_model(4, 2, true, 7);
        let x = Tensor::randn(&[12, 16], 1.0, &mut crate::util::rng::Rng::new(8));
        let (y0, _, _) = moe_forward(&m.layers[0].moe, &x).unwrap();
        let mut moe = m.layers[0].moe.clone();
        moe.map = Some(Tensor::eye(4));
        let (y1, _, _) = moe_forward(&moe, &x).unwrap();
        assert!(y0.rel_err(&y1) < 1e-6);
    }

    #[test]
    fn target_logprobs_alignment() {
        let m = tiny_model(4, 2, false, 9);
        let tokens: Vec<i32> = (0..64).map(|i| (i * 3 % 47) as i32).collect();
        let logits = forward(&m, &tokens, 1, 64, None).unwrap();
        let lps = target_logprobs(&logits, &tokens, 1, 64);
        assert_eq!(lps.len(), 64);
        assert_eq!(lps[63], 0.0); // last position predicts nothing
        assert!(lps[..63].iter().all(|&v| v < 0.0));
    }
}
