//! Native (pure-rust) reference forward engine.
//!
//! Role: (a) bit-level-independent cross-check of the PJRT path — the
//! integration tests require `native ≈ pjrt ≈ python` on identical weights;
//! (b) the compute backend of merge-time math (evaluating member experts on
//! calibration samples); (c) a fallback engine so every experiment can run
//! without artifacts present.
//!
//! Numerics mirror `python/compile/model.py` exactly: pre-LN blocks,
//! softmax-then-top-K routing without renormalization, silu gating,
//! eps=1e-5 layernorm.
//!
//! Every stage comes in two forms: a `*_ws` function that draws all
//! intermediates from a caller-owned [`Workspace`] (the steady-state
//! serving path — zero heap allocations once the arena is warm) and a thin
//! allocating wrapper with the historical signature that spins up a
//! throwaway workspace. Results are bit-identical either way
//! (`tests/workspace_reuse.rs`).
//!
//! Parallelism (see `util::par`): attention fans out per sequence, the MoE
//! gather + SwiGLU phase per expert slot, and the matmul kernels underneath
//! per output row — nested regions degrade to serial automatically, so the
//! layers compose. The down-projection runs as a fused scale-and-scatter
//! GEMM (`ops::matmul_bt_scatter_add_into`), serial in expert order with
//! row-parallel lanes inside (gathered token rows are distinct), keeping
//! results bit-identical at every thread count.
//!
//! Fused epilogues (kernel layer): the expert FFN computes
//! `silu(x W_Gᵀ) ⊙ (x W_Uᵀ)` in one pass ([`expert_swiglu_into`] — the U
//! panel is never materialized), and the merged-expert recombination
//! accumulates `w · (g W_Dᵀ)` straight into the layer output (the
//! per-expert output batch is never materialized). Under the scalar kernel
//! both fusions are arithmetic-identical to the historical unfused path.

use anyhow::{bail, Result};

use super::workspace::{ExpertScratch, KvScratch, Workspace};
use super::{Expert, Layer, ModelWeights, MoeLayer};
use crate::moe::routing::route_tokens_into;
use crate::tensor::{ops, Tensor};
use crate::util::par;

/// Per-layer calibration capture (§4: the sampled inputs X̂ and the routing
/// statistics that define the frequency weights f_i).
#[derive(Debug, Clone)]
pub struct LayerCapture {
    /// Post-LN inputs to the MoE module, one row per token: (T, d).
    pub x: Tensor,
    /// Expert usage counts over these tokens: len E.
    pub counts: Vec<f64>,
    /// Sum of routing weights per expert (soft frequency): len E.
    pub weight_mass: Vec<f64>,
}

/// Typed error for addressing a position past the trained context window:
/// the position table (`pos_emb`) has no row for it, so the forward pass
/// refuses up front instead of panicking on an out-of-bounds index. Callers
/// that drive generation (`eval::sample::generate_into`) stop cleanly at
/// the window instead of tripping this; direct oversized prefills surface
/// it through the `anyhow` chain (`downcast_ref::<ContextOverflow>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ContextOverflow {
    /// First position that has no `pos_emb` row.
    pub pos: usize,
    /// Trained context length (`pos_emb` rows).
    pub context: usize,
}

impl std::fmt::Display for ContextOverflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "position {} is past the trained context window ({} positions)",
            self.pos, self.context
        )
    }
}

impl std::error::Error for ContextOverflow {}

fn dims2(x: &Tensor, what: &str) -> Result<(usize, usize)> {
    match x.shape() {
        [a, b] => Ok((*a, *b)),
        s => bail!("{what} must be 2-D, got {s:?}"),
    }
}

/// The pre-down-projection activations `silu(W_G x) ⊙ (W_U x)` computed
/// into the caller-owned panel `h` (shape (T, f)) by the fused SwiGLU
/// kernel — one pass over `x`, no U intermediate.
pub fn expert_swiglu_into(ex: &Expert, x: &Tensor, h: &mut Tensor) -> Result<()> {
    let (t, _) = dims2(x, "expert input")?;
    let f = ex.wg.shape()[0];
    h.reuse2(t, f);
    ops::swiglu_bt_into(x, &ex.wg, &ex.wu, h)
}

/// Apply one expert to a batch of rows: `W_D (silu(W_G x) ⊙ (W_U x))`.
/// Allocating wrapper around [`expert_swiglu_into`].
pub fn expert_forward(ex: &Expert, x: &Tensor) -> Result<Tensor> {
    let mut g = Tensor::default();
    expert_swiglu_into(ex, x, &mut g)?;
    let mut out = Tensor::default();
    out.reuse2(x.shape()[0], ex.wd.shape()[0]);
    ops::matmul_bt_into(&g, &ex.wd, &mut out)?;
    Ok(out)
}

/// The pre-down-projection activations `silu(W_G x) ⊙ (W_U x)` — the `Q`/`P`
/// rows of the least-squares system (transposed: returned as (T, f)).
pub fn expert_inner(ex: &Expert, x: &Tensor) -> Result<Tensor> {
    let mut g = Tensor::default();
    expert_swiglu_into(ex, x, &mut g)?;
    Ok(g)
}

/// MoE MLP forward on token rows (T, d), all scratch drawn from `ws`.
/// Implements Eq. 1 in the Appendix-B layout: the router scores the N
/// original experts; when `map` (M,N) is set the masked routing vector is
/// redirected to the M real experts (`r' = map · r`).
///
/// Outputs land in the workspace: `ws.moe_out` (T, d), `ws.counts` and
/// `ws.mass` (len E). `x` is typically `ws.x` handed over via
/// `std::mem::take` (a workspace is one coherent arena; the input buffer
/// returns to it afterwards).
pub fn moe_forward_ws(moe: &MoeLayer, x: &Tensor, ws: &mut Workspace) -> Result<()> {
    let (t, d) = dims2(x, "moe input")?;
    let n = moe.router.shape()[0];
    let e = moe.n_experts();
    let k = route_tokens_into(
        &moe.router,
        x,
        moe.top_k,
        &mut ws.route_logits,
        &mut ws.route_order,
        &mut ws.route_pairs,
    )?;
    // dense (t, n) routing weights over the N-way router
    ws.r.reuse2(t, n);
    ws.r.data_mut().fill(0.0);
    for ti in 0..t {
        for &(ei, w) in &ws.route_pairs[ti * k..(ti + 1) * k] {
            *ws.r.at2_mut(ti, ei) = w;
        }
    }
    let r: &Tensor = if let Some(map) = &moe.map {
        ws.r2.reuse2(t, map.shape()[0]);
        ops::matmul_bt_into(&ws.r, map, &mut ws.r2)?; // (t,n) @ (m,n)ᵀ = (t,m)
        &ws.r2
    } else if e != n {
        bail!("moe layer has {e} experts but {n}-way router and no map")
    } else {
        &ws.r
    };
    // Phase 1 (parallel over expert slots): gather each expert's tokens and
    // routing weights, then run the fused SwiGLU panel — tokens may be
    // routed to several experts (top-K), so phase 2's accumulation into
    // `moe_out` stays serial in expert order.
    if ws.experts.len() < e {
        ws.experts.resize_with(e, ExpertScratch::new);
    }
    // rough phase-1 work: top_k experts each run the 2-GEMM SwiGLU panel
    // per routed token — skip the fan-out when the whole batch is tiny
    let f_dim = moe.experts.first().map(|ex| ex.wg.shape()[0]).unwrap_or(0);
    let parallel = 4 * t * moe.top_k * f_dim * d >= par::PAR_MIN_FLOPS;
    {
        let experts = &moe.experts;
        let slots = &mut ws.experts[..e];
        par::par_chunks_mut_if(parallel, slots, 1, |ei, slot| {
            let sc = &mut slot[0];
            sc.err = None;
            sc.tok_idx.clear();
            sc.scales.clear();
            for ti in 0..t {
                let w = r.at2(ti, ei);
                if w != 0.0 {
                    sc.tok_idx.push(ti);
                    sc.scales.push(w);
                }
            }
            let tn = sc.tok_idx.len();
            sc.xs.reuse2(tn, d);
            if tn == 0 {
                return;
            }
            for (row, &ti) in sc.tok_idx.iter().enumerate() {
                sc.xs.row_mut(row).copy_from_slice(x.row(ti));
            }
            if let Err(err) = expert_swiglu_into(&experts[ei], &sc.xs, &mut sc.g) {
                sc.err = Some(err);
            }
        });
    }
    // Phase 2 (serial in expert order, row-parallel inside the kernel): the
    // down-projection runs as a fused scale-and-scatter GEMM straight into
    // `moe_out` — gathered token rows are distinct within one expert, so
    // lanes never collide, and the serial expert loop keeps the per-token
    // accumulation order fixed at every thread count.
    ws.counts.clear();
    ws.counts.resize(e, 0.0);
    ws.mass.clear();
    ws.mass.resize(e, 0.0);
    ws.moe_out.reuse2(t, d);
    ws.moe_out.data_mut().fill(0.0);
    for ei in 0..e {
        let sc = &mut ws.experts[ei];
        if let Some(err) = sc.err.take() {
            return Err(err);
        }
        if sc.tok_idx.is_empty() {
            continue;
        }
        ws.counts[ei] = sc.tok_idx.len() as f64;
        for &w in sc.scales.iter() {
            ws.mass[ei] += w as f64;
        }
        ops::matmul_bt_scatter_add_into(
            &sc.g,
            &moe.experts[ei].wd,
            &sc.scales,
            &sc.tok_idx,
            &mut ws.moe_out,
        )?;
    }
    if let Some(sh) = &moe.shared {
        let sc = &mut ws.shared;
        expert_swiglu_into(sh, x, &mut sc.g)?;
        ops::matmul_bt_scaled_add_into(&sc.g, &sh.wd, 1.0, &mut ws.moe_out)?;
    }
    Ok(())
}

/// MoE MLP forward on token rows (T, d) -> (T, d), plus capture stats.
/// Allocating wrapper around [`moe_forward_ws`].
pub fn moe_forward(moe: &MoeLayer, x: &Tensor) -> Result<(Tensor, Vec<f64>, Vec<f64>)> {
    let mut ws = Workspace::new();
    moe_forward_ws(moe, x, &mut ws)?;
    Ok((
        std::mem::take(&mut ws.moe_out),
        std::mem::take(&mut ws.counts),
        std::mem::take(&mut ws.mass),
    ))
}

/// Causal multi-head attention (pre-LN, residual) on (B, S, d), updating the
/// residual stream `h` in place; all intermediates live in `ws`.
fn attn_forward_ws(
    layer: &Layer,
    h: &mut Tensor,
    n_heads: usize,
    b: usize,
    s: usize,
    ws: &mut Workspace,
) -> Result<()> {
    let d = h.cols();
    let hd = d / n_heads;
    ops::layernorm_into(h, &layer.ln1_g, &layer.ln1_b, &mut ws.x)?;
    ws.q.reuse2(b * s, d);
    ws.k.reuse2(b * s, d);
    ws.v.reuse2(b * s, d);
    ops::matmul_bt_into(&ws.x, &layer.wq, &mut ws.q)?;
    ops::matmul_bt_into(&ws.x, &layer.wk, &mut ws.k)?;
    ops::matmul_bt_into(&ws.x, &layer.wv, &mut ws.v)?;
    let scale = 1.0 / (hd as f32).sqrt();
    ws.ctx.reuse2(b * s, d);
    ws.ctx.data_mut().fill(0.0);
    if b * s > 0 && s > 0 && d > 0 {
        ws.scores.reuse2(b, s);
        let qd = ws.q.data();
        let kd = ws.k.data();
        let vd = ws.v.data();
        // One sequence (an s×d slab of `ctx`) per parallel lane, paired in
        // lockstep with its private scores row from the workspace — no
        // per-sequence allocation. Scores entries [0..=qi] are always
        // written before they are read, so the dirty buffer is fine.
        let parallel = b * s * s * d >= par::PAR_MIN_FLOPS;
        par::par_chunks2_mut_if(
            parallel,
            ws.ctx.data_mut(),
            s * d,
            ws.scores.data_mut(),
            s,
            |bi, cslab, scores| {
                for head in 0..n_heads {
                    let off = head * hd;
                    for qi in 0..s {
                        let qbase = (bi * s + qi) * d + off;
                        let qrow = &qd[qbase..qbase + hd];
                        for ki in 0..=qi {
                            let kbase = (bi * s + ki) * d + off;
                            let krow = &kd[kbase..kbase + hd];
                            let mut dot = 0.0;
                            for (a, b2) in qrow.iter().zip(krow) {
                                dot += a * b2;
                            }
                            scores[ki] = dot * scale;
                        }
                        // softmax over the causal prefix only — entries past
                        // qi are stale scratch and never read
                        let pre = &mut scores[..=qi];
                        let m = pre.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let mut z = 0.0;
                        for v2 in pre.iter_mut() {
                            *v2 = (*v2 - m).exp();
                            z += *v2;
                        }
                        let orow = &mut cslab[qi * d + off..qi * d + off + hd];
                        for ki in 0..=qi {
                            let w = pre[ki] / z;
                            if w == 0.0 {
                                continue;
                            }
                            let vbase = (bi * s + ki) * d + off;
                            let vrow = &vd[vbase..vbase + hd];
                            for (o, &vv) in orow.iter_mut().zip(vrow) {
                                *o += w * vv;
                            }
                        }
                    }
                }
            },
        );
    }
    ws.proj.reuse2(b * s, d);
    ops::matmul_bt_into(&ws.ctx, &layer.wo, &mut ws.proj)?;
    // residual: h += proj (x + 1.0*y is exactly x + y, so this matches the
    // old out-of-place `h.add(&proj)` bit for bit)
    h.axpy(1.0, &ws.proj)
}

/// Single-token causal attention over the cached prefix (the decode twin of
/// [`attn_forward_ws`]): `h` is the one-row residual of the token at
/// `pos`, `kcache`/`vcache` hold rows `0..pos` of this layer's keys/values
/// and receive row `pos` here. The inner arithmetic — per-head dot order,
/// `1/√hd` scaling, max-subtracted softmax over the causal prefix, the
/// `w == 0.0` skip, value accumulation in `ki` order — mirrors
/// [`attn_forward_ws`]'s `qi = pos` iteration exactly, and the QKV/output
/// projections are single-row GEMMs of the same row-independent kernels,
/// so the step is bit-identical to the last row of a full prefill
/// (`tests/decode_consistency.rs`). Serial by construction: one query row
/// is below every parallel threshold.
fn attn_decode_ws(
    layer: &Layer,
    h: &mut Tensor,
    n_heads: usize,
    pos: usize,
    kcache: &mut Tensor,
    vcache: &mut Tensor,
    ws: &mut Workspace,
) -> Result<()> {
    let d = h.cols();
    let hd = d / n_heads;
    ops::layernorm_into(h, &layer.ln1_g, &layer.ln1_b, &mut ws.x)?;
    ws.q.reuse2(1, d);
    ws.k.reuse2(1, d);
    ws.v.reuse2(1, d);
    ops::matmul_bt_into(&ws.x, &layer.wq, &mut ws.q)?;
    ops::matmul_bt_into(&ws.x, &layer.wk, &mut ws.k)?;
    ops::matmul_bt_into(&ws.x, &layer.wv, &mut ws.v)?;
    kcache.row_mut(pos).copy_from_slice(ws.k.row(0));
    vcache.row_mut(pos).copy_from_slice(ws.v.row(0));
    let scale = 1.0 / (hd as f32).sqrt();
    ws.ctx.reuse2(1, d);
    ws.ctx.data_mut().fill(0.0);
    if d > 0 {
        // full-width scores row (the slab capacity, not pos+1) so the
        // buffer reaches its high-water size on the first step and the
        // whole generation stays allocation-free; entries [0..=pos] are
        // written before they are read
        ws.scores.reuse2(1, kcache.shape()[0]);
        let qd = ws.q.data();
        let kd = kcache.data();
        let vd = vcache.data();
        let scores = ws.scores.data_mut();
        let cslab = ws.ctx.data_mut();
        for head in 0..n_heads {
            let off = head * hd;
            let qrow = &qd[off..off + hd];
            for ki in 0..=pos {
                let krow = &kd[ki * d + off..ki * d + off + hd];
                let mut dot = 0.0;
                for (a, b2) in qrow.iter().zip(krow) {
                    dot += a * b2;
                }
                scores[ki] = dot * scale;
            }
            let pre = &mut scores[..=pos];
            let m = pre.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0;
            for v2 in pre.iter_mut() {
                *v2 = (*v2 - m).exp();
                z += *v2;
            }
            let orow = &mut cslab[off..off + hd];
            for ki in 0..=pos {
                let w = pre[ki] / z;
                if w == 0.0 {
                    continue;
                }
                let vrow = &vd[ki * d + off..ki * d + off + hd];
                for (o, &vv) in orow.iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
        }
    }
    ws.proj.reuse2(1, d);
    ops::matmul_bt_into(&ws.ctx, &layer.wo, &mut ws.proj)?;
    h.axpy(1.0, &ws.proj)
}

/// One autoregressive decode step: run `token` at position `kv.len`
/// attending over the cached prefix, append its keys/values to `kv`, and
/// write the next-token logits (1, V) into `logits`. Everything outside
/// attention is per-row arithmetic (embedding, layernorms, the MoE layer on
/// a one-token batch, the head GEMM), so together with [`attn_decode_ws`]
/// the step reproduces the last logits row of a full forward over the
/// prefix bit for bit — the KV cache turns O(S²) re-prefill into O(S) per
/// token without changing a single bit of output.
///
/// Decoding past the trained context window (`pos_emb` rows) returns a
/// typed [`ContextOverflow`] instead of indexing out of bounds. A warm
/// `(kv, ws)` pair decodes with zero heap allocations
/// (`benches/bench_forward.rs` probes the loop).
pub fn decode_step_ws(
    model: &ModelWeights,
    token: i32,
    kv: &mut KvScratch,
    ws: &mut Workspace,
    logits: &mut Tensor,
) -> Result<()> {
    let context = model.pos_emb.shape()[0];
    let pos = kv.len;
    if pos >= context {
        return Err(ContextOverflow { pos, context }.into());
    }
    let d = model.cfg.d_model;
    kv.ensure(model.layers.len(), context, d);
    let mut h = std::mem::take(&mut ws.h);
    h.reuse2(1, d);
    {
        let tk = token as usize;
        for (j, o) in h.data_mut().iter_mut().enumerate() {
            *o = model.tok_emb.at2(tk, j) + model.pos_emb.at2(pos, j);
        }
    }
    for (li, layer) in model.layers.iter().enumerate() {
        attn_decode_ws(
            layer,
            &mut h,
            model.cfg.n_heads,
            pos,
            &mut kv.k[li],
            &mut kv.v[li],
            ws,
        )?;
        ops::layernorm_into(&h, &layer.ln2_g, &layer.ln2_b, &mut ws.x)?;
        let x = std::mem::take(&mut ws.x);
        let moe_result = moe_forward_ws(&layer.moe, &x, ws);
        ws.x = x;
        moe_result?;
        h.axpy(1.0, &ws.moe_out)?;
    }
    ops::layernorm_into(&h, &model.lnf_g, &model.lnf_b, &mut ws.x)?;
    logits.reuse2(1, model.head.shape()[0]);
    ops::matmul_bt_into(&ws.x, &model.head, logits)?;
    ws.h = h;
    kv.len = pos + 1;
    Ok(())
}

/// Full forward pass through a caller-owned workspace. `tokens` is (B, S)
/// of vocab ids; the logits (B·S, V) land in `logits` (resized in place).
/// If `capture` is set, per-layer calibration records are appended (the
/// capture clones allocate — serving passes `None`).
pub fn forward_ws(
    model: &ModelWeights,
    tokens: &[i32],
    b: usize,
    s: usize,
    mut capture: Option<&mut Vec<LayerCapture>>,
    ws: &mut Workspace,
    logits: &mut Tensor,
) -> Result<()> {
    if tokens.len() != b * s {
        bail!("token buffer {} != {b}x{s}", tokens.len());
    }
    let context = model.pos_emb.shape()[0];
    if s > context {
        return Err(ContextOverflow { pos: context, context }.into());
    }
    let d = model.cfg.d_model;
    // embed (row-parallel: token rows are independent)
    let mut h = std::mem::take(&mut ws.h);
    h.reuse2(b * s, d);
    if d > 0 {
        let tok_emb = &model.tok_emb;
        let pos_emb = &model.pos_emb;
        par::par_chunks_mut(h.data_mut(), d, |i, row| {
            let tk = tokens[i] as usize;
            let pos = i % s;
            for (j, o) in row.iter_mut().enumerate() {
                *o = tok_emb.at2(tk, j) + pos_emb.at2(pos, j);
            }
        });
    }
    // layers (on error the taken buffers are simply dropped — the next
    // successful call regrows them)
    for layer in &model.layers {
        attn_forward_ws(layer, &mut h, model.cfg.n_heads, b, s, ws)?;
        ops::layernorm_into(&h, &layer.ln2_g, &layer.ln2_b, &mut ws.x)?;
        let x = std::mem::take(&mut ws.x);
        let moe_result = moe_forward_ws(&layer.moe, &x, ws);
        if moe_result.is_ok() {
            if let Some(cap) = capture.as_deref_mut() {
                cap.push(LayerCapture {
                    x: x.clone(),
                    counts: ws.counts.clone(),
                    weight_mass: ws.mass.clone(),
                });
            }
        }
        ws.x = x; // return the buffer to the arena
        moe_result?;
        h.axpy(1.0, &ws.moe_out)?;
    }
    // head
    ops::layernorm_into(&h, &model.lnf_g, &model.lnf_b, &mut ws.x)?;
    logits.reuse2(b * s, model.head.shape()[0]);
    ops::matmul_bt_into(&ws.x, &model.head, logits)?;
    ws.h = h; // return the residual buffer to the arena
    Ok(())
}

/// Full forward pass. Allocating wrapper around [`forward_ws`]: spins up a
/// throwaway workspace, so callers that serve at steady state should hold
/// their own and call [`forward_ws`] directly.
pub fn forward(
    model: &ModelWeights,
    tokens: &[i32],
    b: usize,
    s: usize,
    capture: Option<&mut Vec<LayerCapture>>,
) -> Result<Tensor> {
    let mut ws = Workspace::new();
    let mut logits = Tensor::default();
    forward_ws(model, tokens, b, s, capture, &mut ws, &mut logits)?;
    Ok(logits)
}

/// Log-probabilities of `targets[i]` under a causal LM, written into a
/// reusable buffer: `logits` (B·S, V) row i predicts token i+1 of the same
/// sequence; `out[last position of each sequence]` stays 0. Computes each
/// row's log-partition directly (identical arithmetic to a full
/// `log_softmax_rows`, minus materializing the (B·S, V) matrix).
pub fn target_logprobs_into(
    logits: &Tensor,
    tokens: &[i32],
    b: usize,
    s: usize,
    out: &mut Vec<f32>,
) {
    let v = logits.cols();
    out.clear();
    out.resize(b * s, 0.0);
    if s == 0 || v == 0 {
        return;
    }
    let ld = logits.data();
    let parallel = b * s * v >= par::PAR_MIN_ELEMS;
    par::par_chunks_mut_if(parallel, out.as_mut_slice(), s, |bi, oseq| {
        for si in 0..s - 1 {
            let row = bi * s + si;
            let rowd = &ld[row * v..(row + 1) * v];
            let m = rowd.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = rowd.iter().map(|val| (val - m).exp()).sum();
            let lz = z.ln() + m;
            oseq[si] = rowd[tokens[bi * s + si + 1] as usize] - lz;
        }
    });
}

/// Allocating wrapper around [`target_logprobs_into`].
pub fn target_logprobs(logits: &Tensor, tokens: &[i32], b: usize, s: usize) -> Vec<f32> {
    let mut out = Vec::new();
    target_logprobs_into(logits, tokens, b, s, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = tiny_model(4, 2, true, 3);
        let tokens: Vec<i32> = (0..2 * 64).map(|i| (i % 47) as i32).collect();
        let logits = forward(&m, &tokens, 2, 64, None).unwrap();
        assert_eq!(logits.shape(), &[128, 47]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn capture_collects_all_layers() {
        let m = tiny_model(4, 2, false, 4);
        let tokens: Vec<i32> = (0..64).map(|i| (i % 47) as i32).collect();
        let mut cap = Vec::new();
        forward(&m, &tokens, 1, 64, Some(&mut cap)).unwrap();
        assert_eq!(cap.len(), 2);
        assert_eq!(cap[0].x.shape(), &[64, 16]);
        // top-2 of 4 experts over 64 tokens: total count = 128
        let total: f64 = cap[0].counts.iter().sum();
        assert_eq!(total, 128.0);
        assert!(cap[0].weight_mass.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn moe_forward_is_topk_sparse_mixture() {
        let m = tiny_model(8, 2, false, 5);
        let moe = &m.layers[0].moe;
        let x = Tensor::randn(&[10, 16], 1.0, &mut crate::util::rng::Rng::new(6));
        let (y, counts, _) = moe_forward(moe, &x).unwrap();
        assert_eq!(y.shape(), &[10, 16]);
        assert_eq!(counts.iter().sum::<f64>(), 20.0);
        // manual recomputation for token 0
        let routing = crate::moe::routing::route_tokens(&moe.router, &x, 2).unwrap();
        let x0 = x.rows_slice(0, 1);
        let mut want = Tensor::zeros(&[1, 16]);
        for &(ei, w) in &routing[0] {
            let e_out = expert_forward(&moe.experts[ei], &x0).unwrap();
            want.axpy(w, &e_out).unwrap();
        }
        let got = y.rows_slice(0, 1);
        assert!(got.rel_err(&want) < 1e-5);
    }

    #[test]
    fn identity_map_is_noop() {
        let m = tiny_model(4, 2, true, 7);
        let x = Tensor::randn(&[12, 16], 1.0, &mut crate::util::rng::Rng::new(8));
        let (y0, _, _) = moe_forward(&m.layers[0].moe, &x).unwrap();
        let mut moe = m.layers[0].moe.clone();
        moe.map = Some(Tensor::eye(4));
        let (y1, _, _) = moe_forward(&moe, &x).unwrap();
        assert!(y0.rel_err(&y1) < 1e-6);
    }

    #[test]
    fn decode_steps_match_full_prefill_rows() {
        let m = tiny_model(4, 2, true, 11);
        let tokens: Vec<i32> = (0..12).map(|i| (i * 7 % 47) as i32).collect();
        let mut kv = KvScratch::new();
        let mut ws = Workspace::new();
        let mut step = Tensor::default();
        for (t, &tok) in tokens.iter().enumerate() {
            decode_step_ws(&m, tok, &mut kv, &mut ws, &mut step).unwrap();
            let full = forward(&m, &tokens[..=t], 1, t + 1, None).unwrap();
            assert_eq!(step.data(), full.rows_slice(t, t + 1).data(), "step {t}");
        }
        assert_eq!(kv.len, tokens.len());
    }

    #[test]
    fn decode_past_context_is_typed_overflow() {
        let m = tiny_model(4, 2, false, 12);
        let context = m.pos_emb.shape()[0];
        let mut kv = KvScratch::new();
        let mut ws = Workspace::new();
        let mut step = Tensor::default();
        for _ in 0..context {
            decode_step_ws(&m, 3, &mut kv, &mut ws, &mut step).unwrap();
        }
        let err = decode_step_ws(&m, 3, &mut kv, &mut ws, &mut step).unwrap_err();
        let ov = err
            .downcast_ref::<ContextOverflow>()
            .expect("context overflow must be typed");
        assert_eq!(*ov, ContextOverflow { pos: context, context });
        assert_eq!(kv.len, context, "failed step must not advance the cache");
    }

    #[test]
    fn oversized_prefill_is_typed_overflow() {
        let m = tiny_model(4, 2, false, 13);
        let context = m.pos_emb.shape()[0];
        let tokens: Vec<i32> = (0..context as i32 + 1).map(|i| i % 47).collect();
        let err = forward(&m, &tokens, 1, context + 1, None).unwrap_err();
        assert!(
            err.downcast_ref::<ContextOverflow>().is_some(),
            "oversized prefill must fail typed, got {err:#}"
        );
    }

    #[test]
    fn target_logprobs_alignment() {
        let m = tiny_model(4, 2, false, 9);
        let tokens: Vec<i32> = (0..64).map(|i| (i * 3 % 47) as i32).collect();
        let logits = forward(&m, &tokens, 1, 64, None).unwrap();
        let lps = target_logprobs(&logits, &tokens, 1, 64);
        assert_eq!(lps.len(), 64);
        assert_eq!(lps[63], 0.0); // last position predicts nothing
        assert!(lps[..63].iter().all(|&v| v < 0.0));
    }

    #[test]
    fn target_logprobs_matches_full_log_softmax() {
        // the direct per-row log-partition must equal reading the entry out
        // of the materialized log-softmax matrix, bit for bit
        let m = tiny_model(4, 2, true, 10);
        let tokens: Vec<i32> = (0..2 * 64).map(|i| (i * 5 % 47) as i32).collect();
        let logits = forward(&m, &tokens, 2, 64, None).unwrap();
        let got = target_logprobs(&logits, &tokens, 2, 64);
        let lp = ops::log_softmax_rows(&logits);
        for bi in 0..2 {
            for si in 0..63 {
                let row = bi * 64 + si;
                let want = lp.at2(row, tokens[bi * 64 + si + 1] as usize);
                assert_eq!(got[row], want, "row {row}");
            }
            assert_eq!(got[bi * 64 + 63], 0.0);
        }
    }
}
