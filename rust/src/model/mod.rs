//! Model weights: loading from the trainer's NPZ dump, per-layer expert
//! storage (experts are kept as individual matrices so merge algorithms can
//! splice them), and export back to NPZ.

pub mod native;
pub mod workspace;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::io::npz;
use crate::tensor::Tensor;

/// One routed SwiGLU expert: `E(x) = W_D (silu(W_G x) ⊙ (W_U x))`.
#[derive(Debug, Clone)]
pub struct Expert {
    pub wg: Tensor, // (f, d)
    pub wu: Tensor, // (f, d)
    pub wd: Tensor, // (d, f)
}

impl Expert {
    /// Parameter count (the unit of the paper's memory accounting).
    pub fn n_params(&self) -> usize {
        self.wg.len() + self.wu.len() + self.wd.len()
    }
}

/// The MoE MLP of one transformer layer, in the paper's Appendix-B layout:
/// the router always stays N-way (N = original expert count), and a routing
/// map redirects the top-K mass to the M *real* experts.
#[derive(Debug, Clone)]
pub struct MoeLayer {
    pub router: Tensor,       // (N, d) — row j scores original expert j
    pub experts: Vec<Expert>, // length M (shrinks after merging)
    pub shared: Option<Expert>,
    pub top_k: usize,
    /// Routing map (M, N): `None` ⇔ identity (uncompressed, M = N).
    /// Merged layers carry the summation matrix A of Eq. 2; the Table-5
    /// oracle carries B·A (original experts kept, outputs merged exactly).
    pub map: Option<Tensor>,
}

impl MoeLayer {
    pub fn n_experts(&self) -> usize {
        self.experts.len()
    }

    /// Stack per-expert matrices into the (E,f,d)/(E,d,f) layout the PJRT
    /// artifacts take as parameters.
    pub fn stacked(&self) -> (Tensor, Tensor, Tensor) {
        let e = self.experts.len();
        let (f, d) = {
            let s = self.experts[0].wg.shape();
            (s[0], s[1])
        };
        let mut wg = Vec::with_capacity(e * f * d);
        let mut wu = Vec::with_capacity(e * f * d);
        let mut wd = Vec::with_capacity(e * f * d);
        for ex in &self.experts {
            wg.extend_from_slice(ex.wg.data());
            wu.extend_from_slice(ex.wu.data());
            wd.extend_from_slice(ex.wd.data());
        }
        (
            Tensor::from_vec(&[e, f, d], wg).unwrap(),
            Tensor::from_vec(&[e, f, d], wu).unwrap(),
            Tensor::from_vec(&[e, d, f], wd).unwrap(),
        )
    }
}

/// One transformer layer (attention + MoE MLP, both pre-LN residual).
#[derive(Debug, Clone)]
pub struct Layer {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub moe: MoeLayer,
}

/// Full model weights.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub tok_emb: Tensor, // (V, d)
    pub pos_emb: Tensor, // (S, d)
    pub layers: Vec<Layer>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub head: Tensor, // (V, d)
    /// Weight-version identity for runtime-side caching (staged device
    /// literals are keyed by this). Freshly assigned on load; **any code
    /// that mutates weights must call [`ModelWeights::touch`]** — the
    /// compression pipeline and the distillation refit do.
    pub uid: u64,
}

/// Monotonic uid source for [`ModelWeights::touch`].
static NEXT_UID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

pub fn fresh_uid() -> u64 {
    NEXT_UID.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
}

impl ModelWeights {
    /// Load `weights_<name>.npz` as written by `python/compile/train.py`.
    pub fn load(dir: &Path, cfg: &ModelConfig) -> Result<ModelWeights> {
        let path = dir.join(format!("weights_{}.npz", cfg.name));
        let m = npz::read_npz_tensors(&path)
            .with_context(|| format!("loading weights for model {}", cfg.name))?;
        Self::from_arrays(m, cfg).with_context(|| format!("loading weights for model {}", cfg.name))
    }

    /// Assemble weights from a flat name → tensor map (the NPZ key layout).
    ///
    /// Accepts both uncompressed dumps (`L{i}.wg` stacked `(N,f,d)`, no
    /// map) and merged-variant exports: when `L{i}.map` is present the
    /// expert stack may hold `M ≤ N` experts and the `(M,N)` map redirects
    /// the N-way router onto them (the registry round-trips compressed
    /// variants through exactly this path).
    pub fn from_arrays(
        mut m: BTreeMap<String, Tensor>,
        cfg: &ModelConfig,
    ) -> Result<ModelWeights> {
        let mut maps: Vec<Option<Tensor>> =
            (0..cfg.n_layers).map(|i| m.remove(&format!("L{i}.map"))).collect();
        let mut take = |k: &str| -> Result<Tensor> {
            m.remove(k).with_context(|| format!("weights missing key {k:?}"))
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let pre = |n: &str| format!("L{i}.{n}");
            let map = maps[i].take();
            let wg = take(&pre("wg"))?;
            let wu = take(&pre("wu"))?;
            let wd = take(&pre("wd"))?;
            let experts = split_experts(&wg, &wu, &wd, cfg, map.is_none())?;
            if let Some(map) = &map {
                if map.shape() != [experts.len(), cfg.n_experts] {
                    bail!(
                        "L{i}.map shape {:?} disagrees with {} experts over an {}-way router",
                        map.shape(),
                        experts.len(),
                        cfg.n_experts
                    );
                }
            }
            let shared = if cfg.shared_expert {
                Some(Expert {
                    wg: take(&pre("swg"))?,
                    wu: take(&pre("swu"))?,
                    wd: take(&pre("swd"))?,
                })
            } else {
                None
            };
            layers.push(Layer {
                ln1_g: take(&pre("ln1_g"))?.into_vec(),
                ln1_b: take(&pre("ln1_b"))?.into_vec(),
                wq: take(&pre("wq"))?,
                wk: take(&pre("wk"))?,
                wv: take(&pre("wv"))?,
                wo: take(&pre("wo"))?,
                ln2_g: take(&pre("ln2_g"))?.into_vec(),
                ln2_b: take(&pre("ln2_b"))?.into_vec(),
                moe: MoeLayer {
                    router: take(&pre("router"))?,
                    experts,
                    shared,
                    top_k: cfg.top_k,
                    map,
                },
            });
        }
        Ok(ModelWeights {
            cfg: cfg.clone(),
            tok_emb: take("tok_emb")?,
            pos_emb: take("pos_emb")?,
            layers,
            lnf_g: take("lnf_g")?.into_vec(),
            lnf_b: take("lnf_b")?.into_vec(),
            head: take("head")?,
            uid: fresh_uid(),
        })
    }

    /// Declare the weights modified: invalidates any runtime-side caches
    /// keyed on this model's identity.
    pub fn touch(&mut self) {
        self.uid = fresh_uid();
    }

    /// Total parameter count (matches `configs.py::n_params` before merging,
    /// and accounts per-layer expert counts after).
    pub fn n_params(&self) -> usize {
        let mut n = self.tok_emb.len() + self.pos_emb.len() + self.head.len()
            + self.lnf_g.len() + self.lnf_b.len();
        for l in &self.layers {
            n += l.ln1_g.len() + l.ln1_b.len() + l.ln2_g.len() + l.ln2_b.len();
            n += l.wq.len() + l.wk.len() + l.wv.len() + l.wo.len();
            n += l.moe.router.len();
            n += l.moe.experts.iter().map(Expert::n_params).sum::<usize>();
            if let Some(s) = &l.moe.shared {
                n += s.n_params();
            }
        }
        n
    }

    /// Export back to a flat NPZ (compressed-model artifact for deployment;
    /// also used by tests to round-trip).
    pub fn save(&self, path: &Path) -> Result<()> {
        npz::write_npz(path, &self.to_arrays()?)
    }

    /// Flatten to the NPZ key layout ([`ModelWeights::from_arrays`] is the
    /// inverse). Merged variants serialize their `(M,N)` routing maps as
    /// `L{i}.map`; without them a compressed model would reload unservable
    /// (M experts under an N-way router with no redirect).
    pub fn to_arrays(&self) -> Result<BTreeMap<String, Tensor>> {
        let mut m: BTreeMap<String, Tensor> = BTreeMap::new();
        m.insert("tok_emb".into(), self.tok_emb.clone());
        m.insert("pos_emb".into(), self.pos_emb.clone());
        m.insert("lnf_g".into(), Tensor::from_vec(&[self.lnf_g.len()], self.lnf_g.clone())?);
        m.insert("lnf_b".into(), Tensor::from_vec(&[self.lnf_b.len()], self.lnf_b.clone())?);
        m.insert("head".into(), self.head.clone());
        for (i, l) in self.layers.iter().enumerate() {
            let pre = |n: &str| format!("L{i}.{n}");
            m.insert(pre("ln1_g"), Tensor::from_vec(&[l.ln1_g.len()], l.ln1_g.clone())?);
            m.insert(pre("ln1_b"), Tensor::from_vec(&[l.ln1_b.len()], l.ln1_b.clone())?);
            m.insert(pre("ln2_g"), Tensor::from_vec(&[l.ln2_g.len()], l.ln2_g.clone())?);
            m.insert(pre("ln2_b"), Tensor::from_vec(&[l.ln2_b.len()], l.ln2_b.clone())?);
            m.insert(pre("wq"), l.wq.clone());
            m.insert(pre("wk"), l.wk.clone());
            m.insert(pre("wv"), l.wv.clone());
            m.insert(pre("wo"), l.wo.clone());
            m.insert(pre("router"), l.moe.router.clone());
            let (wg, wu, wd) = l.moe.stacked();
            m.insert(pre("wg"), wg);
            m.insert(pre("wu"), wu);
            m.insert(pre("wd"), wd);
            if let Some(s) = &l.moe.shared {
                m.insert(pre("swg"), s.wg.clone());
                m.insert(pre("swu"), s.wu.clone());
                m.insert(pre("swd"), s.wd.clone());
            }
            if let Some(map) = &l.moe.map {
                m.insert(pre("map"), map.clone());
            }
        }
        Ok(m)
    }
}

/// Split a stacked `(E,f,d)` dump into per-expert matrices. `expect_full`
/// demands `E == cfg.n_experts` (uncompressed dumps, where no routing map
/// exists to account for a different count); merged variants pass `false`
/// and the map shape check in [`ModelWeights::from_arrays`] ties `E` down.
fn split_experts(
    wg: &Tensor,
    wu: &Tensor,
    wd: &Tensor,
    cfg: &ModelConfig,
    expect_full: bool,
) -> Result<Vec<Expert>> {
    let (e, f, d) = match wg.shape() {
        [e, f, d] => (*e, *f, *d),
        s => bail!("expert stack must be 3-D, got {s:?}"),
    };
    if (expect_full && e != cfg.n_experts) || f != cfg.d_ff || d != cfg.d_model {
        bail!("expert stack shape {:?} disagrees with config {}x{}x{}",
              wg.shape(), cfg.n_experts, cfg.d_ff, cfg.d_model);
    }
    if e == 0 || e > cfg.n_experts {
        bail!("expert stack has {e} experts (config allows 1..={})", cfg.n_experts);
    }
    if wu.shape() != [e, f, d] || wd.shape() != [e, d, f] {
        bail!(
            "expert stacks disagree: wg {:?}, wu {:?}, wd {:?}",
            wg.shape(),
            wu.shape(),
            wd.shape()
        );
    }
    let mut out = Vec::with_capacity(e);
    for i in 0..e {
        let slice = |t: &Tensor, rows: usize, cols: usize| {
            Tensor::from_vec(
                &[rows, cols],
                t.data()[i * rows * cols..(i + 1) * rows * cols].to_vec(),
            )
            .unwrap()
        };
        out.push(Expert {
            wg: slice(wg, f, d),
            wu: slice(wu, f, d),
            wd: slice(wd, d, f),
        });
    }
    Ok(out)
}

/// Synthetic-model builders for the crate's integration/property tests
/// (public so `tests/*.rs` can use them; hidden from docs).
#[doc(hidden)]
pub mod testprops {
    use super::{fresh_uid, Expert, Layer, ModelWeights, MoeLayer};
    use crate::config::ModelConfig;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    /// A random MoE layer with `n` experts over d=16, f=8 (matches
    /// `testutil::tiny_model`'s layer shape).
    pub fn tiny_moe(n: usize, top_k: usize, seed: u64) -> MoeLayer {
        let mut rng = Rng::new(seed ^ 0x7E57_0000);
        let (d, f) = (16, 8);
        let mk = |rng: &mut Rng| Expert {
            wg: Tensor::randn(&[f, d], 0.3, rng),
            wu: Tensor::randn(&[f, d], 0.3, rng),
            wd: Tensor::randn(&[d, f], 0.3, rng),
        };
        MoeLayer {
            router: Tensor::randn(&[n, d], 0.4, &mut rng),
            experts: (0..n).map(|_| mk(&mut rng)).collect(),
            shared: None,
            top_k,
            map: None,
        }
    }

    /// A fully synthetic model with the given config (vocab 47 / seq 64,
    /// matching the task corpus). Used by benches and property tests when no
    /// trained NPZ artifacts are on disk; deterministic in `seed`.
    pub fn synth_model(cfg: &ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let e = cfg.n_experts;
        let v = 47;
        let s = 64;
        let mk_expert = |rng: &mut Rng| Expert {
            wg: Tensor::randn(&[f, d], 0.3, rng),
            wu: Tensor::randn(&[f, d], 0.3, rng),
            wd: Tensor::randn(&[d, f], 0.3, rng),
        };
        let layers = (0..cfg.n_layers)
            .map(|_| Layer {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                wq: Tensor::randn(&[d, d], 0.2, &mut rng),
                wk: Tensor::randn(&[d, d], 0.2, &mut rng),
                wv: Tensor::randn(&[d, d], 0.2, &mut rng),
                wo: Tensor::randn(&[d, d], 0.2, &mut rng),
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                moe: MoeLayer {
                    router: Tensor::randn(&[e, d], 0.4, &mut rng),
                    experts: (0..e).map(|_| mk_expert(&mut rng)).collect(),
                    shared: if cfg.shared_expert { Some(mk_expert(&mut rng)) } else { None },
                    top_k: cfg.top_k,
                    map: None,
                },
            })
            .collect();
        ModelWeights {
            cfg: cfg.clone(),
            tok_emb: Tensor::randn(&[v, d], 0.5, &mut rng),
            pos_emb: Tensor::randn(&[s, d], 0.1, &mut rng),
            layers,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
            head: Tensor::randn(&[v, d], 0.3, &mut rng),
            uid: fresh_uid(),
        }
    }
}

#[cfg(test)]
pub mod testutil {
    //! Synthetic model builder shared by unit tests across modules.
    use super::*;

    pub fn tiny_config(e: usize, k: usize, shared: bool) -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            n_layers: 2,
            d_model: 16,
            n_heads: 2,
            d_ff: 8,
            n_experts: e,
            top_k: k,
            shared_expert: shared,
            n_params: 0,
            merge_targets: vec![e / 2],
        }
    }

    pub fn tiny_model(e: usize, k: usize, shared: bool, seed: u64) -> ModelWeights {
        // Same RNG draw order/scales as before the refactor — seeds keep
        // producing identical weights (tests depend on them).
        super::testprops::synth_model(&tiny_config(e, k, shared), seed)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::tiny_model;
    use super::*;

    #[test]
    fn stacked_roundtrip() {
        let m = tiny_model(4, 2, true, 1);
        let moe = &m.layers[0].moe;
        let (wg, _, wd) = moe.stacked();
        assert_eq!(wg.shape(), &[4, 8, 16]);
        assert_eq!(wd.shape(), &[4, 16, 8]);
        assert_eq!(&wg.data()[0..moe.experts[0].wg.len()], moe.experts[0].wg.data());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("mergemoe_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let m = tiny_model(4, 2, true, 2);
        let path = dir.join("weights_tiny.npz");
        m.save(&path).unwrap();
        let back = ModelWeights::load(&dir, &m.cfg).unwrap();
        assert_eq!(back.layers.len(), 2);
        assert_eq!(back.layers[1].moe.experts.len(), 4);
        assert_eq!(
            back.layers[1].moe.experts[3].wd.data(),
            m.layers[1].moe.experts[3].wd.data()
        );
        assert_eq!(back.n_params(), m.n_params());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn merged_variant_roundtrip_keeps_map() {
        let dir = std::env::temp_dir().join("mergemoe_model_test_merged");
        std::fs::create_dir_all(&dir).unwrap();
        let mut m = tiny_model(4, 2, false, 3);
        for l in &mut m.layers {
            l.moe.experts.truncate(2);
            l.moe.map = Some(
                Tensor::from_vec(&[2, 4], vec![1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 1.0]).unwrap(),
            );
        }
        m.touch();
        let path = dir.join("weights_tiny.npz");
        m.save(&path).unwrap();
        let back = ModelWeights::load(&dir, &m.cfg).unwrap();
        assert_eq!(back.layers[0].moe.experts.len(), 2);
        let map = back.layers[1].moe.map.as_ref().expect("map survives the round-trip");
        assert_eq!(map.shape(), &[2, 4]);
        assert_eq!(map.data(), m.layers[1].moe.map.as_ref().unwrap().data());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reduced_stack_without_map_is_rejected() {
        let m = tiny_model(4, 2, false, 4);
        let mut arrays = m.to_arrays().unwrap();
        // Drop the map-less model's stack down to 2 experts: unservable.
        for key in ["L0.wg", "L0.wu", "L0.wd"] {
            let t = arrays.remove(key).unwrap();
            let half = t.len() / 2;
            let s = t.shape().to_vec();
            arrays.insert(
                key.into(),
                Tensor::from_vec(&[2, s[1], s[2]], t.data()[..half].to_vec()).unwrap(),
            );
        }
        assert!(ModelWeights::from_arrays(arrays, &m.cfg).is_err());
    }
}
