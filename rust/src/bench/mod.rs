//! Criterion-style benchmark harness (criterion itself is unavailable in the
//! offline build). Provides warm-up, timed iterations, robust summary
//! statistics, and machine-readable `BENCH_<name>.json` reports so future
//! PRs have a perf trajectory to compare against; the `benches/` targets
//! (built with `harness = false`) and the §Perf pass are built on this.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p90: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl Summary {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n / self.mean.as_secs_f64())
    }

    /// Machine-readable record (durations in seconds).
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("mean_s", Json::num(self.mean.as_secs_f64())),
            ("p50_s", Json::num(self.p50.as_secs_f64())),
            ("p90_s", Json::num(self.p90.as_secs_f64())),
            ("p99_s", Json::num(self.p99.as_secs_f64())),
            ("min_s", Json::num(self.min.as_secs_f64())),
            ("max_s", Json::num(self.max.as_secs_f64())),
            ("items_per_iter", opt(self.items_per_iter)),
            ("items_per_s", opt(self.throughput())),
        ])
    }

    /// One human-readable report line (also the `cargo bench` output format).
    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1000.0 => format!("  [{:.1}k items/s]", t / 1000.0),
            Some(t) => format!("  [{t:.1} items/s]"),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}  ({} iters){}",
            self.name, self.mean, self.p50, self.p99, self.iters, tp
        )
    }
}

/// Benchmark runner with a wall-clock budget per benchmark.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            budget: Duration::from_secs(5),
        }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher { warmup_iters: 1, min_iters: 3, max_iters: 50,
                  budget: Duration::from_secs(2) }
    }

    /// [`Bencher::quick`] when [`quick_mode`] is on (CI runs every bench in
    /// quick mode on every PR), [`Bencher::default`] otherwise.
    pub fn from_env() -> Bencher {
        if quick_mode() {
            Bencher::quick()
        } else {
            Bencher::default()
        }
    }

    /// Run `f` repeatedly; the closure's return value is black-boxed so LLVM
    /// cannot elide the work.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Summary {
        self.run_with_items(name, None, &mut f)
    }

    /// Like [`Bencher::run`], with a throughput denominator.
    pub fn run_items<T>(&self, name: &str, items: f64, mut f: impl FnMut() -> T) -> Summary {
        self.run_with_items(name, Some(items), &mut f)
    }

    fn run_with_items<T>(
        &self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> Summary {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let start = Instant::now();
        let mut samples: Vec<Duration> = Vec::new();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
        Summary {
            name: name.to_string(),
            iters: n,
            mean: total / n as u32,
            p50: pct(0.50),
            p90: pct(0.90),
            p99: pct(0.99),
            min: samples[0],
            max: samples[n - 1],
            items_per_iter: items,
        }
    }
}

/// Whether `MERGEMOE_BENCH_QUICK` requests the fast bench profile — the
/// single definition of the truthiness rule, shared by
/// [`Bencher::from_env`] and benches that also scale their *workload*
/// (e.g. `bench_gemm`'s shape sweep) to the profile.
pub fn quick_mode() -> bool {
    match std::env::var("MERGEMOE_BENCH_QUICK") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// A model resolved for benchmarking: trained artifacts when present, a
/// synthetic stand-in of the same published shape otherwise.
pub struct BenchModel {
    pub model: crate::model::ModelWeights,
    pub seq_len: usize,
    pub from_artifacts: bool,
}

/// Load `name` from the artifacts directory, or fall back to a synthetic
/// model so the `benches/` targets run (and the kernel-level numbers stay
/// meaningful) on a bare checkout with no trained NPZ artifacts.
pub fn load_or_synth(name: &str) -> BenchModel {
    use crate::exp::{Ctx, EngineSel};
    if let Ok(ctx) = Ctx::new(crate::config::artifacts_dir(), EngineSel::Native) {
        if let Ok(model) = ctx.load_model(name) {
            return BenchModel { model, seq_len: ctx.manifest.seq_len, from_artifacts: true };
        }
    }
    // Shape of the published `beta` config (configs.py): the model every
    // bench quotes numbers on.
    let cfg = crate::config::ModelConfig {
        name: name.to_string(),
        n_layers: 4,
        d_model: 64,
        n_heads: 4,
        d_ff: 64,
        n_experts: 12,
        top_k: 2,
        shared_expert: true,
        n_params: 0,
        merge_targets: vec![2, 3, 4, 6, 8, 10],
    };
    BenchModel {
        model: crate::model::testprops::synth_model(&cfg, 0xBE7A),
        seq_len: 64,
        from_artifacts: false,
    }
}

/// Write `BENCH_<name>.json` into `dir` with every summary plus the thread
/// count and compute kernel the run used (so the bench-diff trajectory can
/// tell kernel drift from real regressions). The kernel field records the
/// process's *default dispatch* at write time; benches that deliberately
/// force kernels per entry (`bench_gemm`) carry the real kernel in each
/// entry's name. Returns the path written.
pub fn write_report_to(dir: &Path, name: &str, summaries: &[Summary]) -> Result<PathBuf> {
    let path = dir.join(format!("BENCH_{name}.json"));
    let json = Json::obj(vec![
        ("bench", Json::str(name)),
        ("threads", Json::num(crate::util::par::max_threads() as f64)),
        ("kernel", Json::str(crate::kernel::name())),
        ("results", Json::arr(summaries.iter().map(Summary::to_json))),
    ]);
    std::fs::write(&path, json.to_string())
        .with_context(|| format!("writing bench report {}", path.display()))?;
    Ok(path)
}

/// [`write_report_to`] with the directory taken from `MERGEMOE_BENCH_DIR`
/// (default `.`, which `.gitignore` covers) — the entry point the
/// `benches/` targets use.
pub fn write_report(name: &str, summaries: &[Summary]) -> Result<PathBuf> {
    let dir = std::env::var("MERGEMOE_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    write_report_to(Path::new(&dir), name, summaries)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleeps_roughly() {
        let b = Bencher { warmup_iters: 0, min_iters: 3, max_iters: 5,
                          budget: Duration::from_millis(100) };
        let s = b.run("sleep", || std::thread::sleep(Duration::from_millis(5)));
        assert!(s.mean >= Duration::from_millis(4), "{:?}", s.mean);
        assert!(s.iters >= 3);
        assert!(s.min <= s.p50 && s.p50 <= s.max);
    }

    #[test]
    fn throughput_reported() {
        let b = Bencher::quick();
        let s = b.run_items("noop", 100.0, || 1 + 1);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(s.report().contains("items/s"));
    }

    #[test]
    fn json_report_roundtrips() {
        let b = Bencher::quick();
        let s = b.run_items("noop", 10.0, || 1 + 1);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str().unwrap(), "noop");
        assert!(parsed.get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(parsed.get("items_per_iter").unwrap().as_f64().unwrap(), 10.0);

        let dir = std::env::temp_dir().join("mergemoe_bench_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = write_report_to(&dir, "unit", &[s]).unwrap();
        let back = Json::parse_file(&path).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str().unwrap(), "unit");
        assert_eq!(back.get("results").unwrap().as_arr().unwrap().len(), 1);
        assert!(back.get("threads").unwrap().as_usize().unwrap() >= 1);
        std::fs::remove_file(&path).ok();
    }
}
