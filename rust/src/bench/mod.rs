//! Criterion-style benchmark harness (criterion itself is unavailable in the
//! offline build). Provides warm-up, timed iterations, and robust summary
//! statistics; the `benches/` targets (built with `harness = false`) and the
//! §Perf pass are built on this.

use std::time::{Duration, Instant};

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p90: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl Summary {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n / self.mean.as_secs_f64())
    }

    /// One human-readable report line (also the `cargo bench` output format).
    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1000.0 => format!("  [{:.1}k items/s]", t / 1000.0),
            Some(t) => format!("  [{t:.1} items/s]"),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}  ({} iters){}",
            self.name, self.mean, self.p50, self.p99, self.iters, tp
        )
    }
}

/// Benchmark runner with a wall-clock budget per benchmark.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            budget: Duration::from_secs(5),
        }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher { warmup_iters: 1, min_iters: 3, max_iters: 50,
                  budget: Duration::from_secs(2) }
    }

    /// Run `f` repeatedly; the closure's return value is black-boxed so LLVM
    /// cannot elide the work.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Summary {
        self.run_with_items(name, None, &mut f)
    }

    /// Like [`Bencher::run`], with a throughput denominator.
    pub fn run_items<T>(&self, name: &str, items: f64, mut f: impl FnMut() -> T) -> Summary {
        self.run_with_items(name, Some(items), &mut f)
    }

    fn run_with_items<T>(
        &self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> Summary {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let start = Instant::now();
        let mut samples: Vec<Duration> = Vec::new();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
        Summary {
            name: name.to_string(),
            iters: n,
            mean: total / n as u32,
            p50: pct(0.50),
            p90: pct(0.90),
            p99: pct(0.99),
            min: samples[0],
            max: samples[n - 1],
            items_per_iter: items,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleeps_roughly() {
        let b = Bencher { warmup_iters: 0, min_iters: 3, max_iters: 5,
                          budget: Duration::from_millis(100) };
        let s = b.run("sleep", || std::thread::sleep(Duration::from_millis(5)));
        assert!(s.mean >= Duration::from_millis(4), "{:?}", s.mean);
        assert!(s.iters >= 3);
        assert!(s.min <= s.p50 && s.p50 <= s.max);
    }

    #[test]
    fn throughput_reported() {
        let b = Bencher::quick();
        let s = b.run_items("noop", 100.0, || 1 + 1);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(s.report().contains("items/s"));
    }
}
