//! Vendored, dependency-free reimplementation of the subset of the `anyhow`
//! API this workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment has no registry access, so the real crate cannot be
//! fetched; this stand-in keeps the same call-site syntax and semantics
//! (context chaining, `{:#}` alternate formatting, `From<impl std::error::
//! Error>`, and [`Error::downcast_ref`] to the originating typed error).
//! The context chain is stored as plain strings; the original error value
//! is additionally kept as an `Any` payload so typed recovery — the fault
//! classifier's `InjectedFault`, the registry's `RegistryError` — works
//! through any number of `.context(..)` wrappers, exactly as with the real
//! crate.

use std::any::Any;
use std::fmt::{self, Display};

/// `anyhow::Result<T>` — result alias with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. Deliberately does **not** implement
/// `std::error::Error`, exactly like the real `anyhow::Error`, so the blanket
/// `From<E: std::error::Error>` impl below is coherent.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    /// The original typed error value (when constructed from one), kept so
    /// `downcast_ref` can recover it through context wrappers.
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None, payload: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)), payload: None }
    }

    /// Recover the originating typed error, searching the context chain
    /// outermost-first (real-`anyhow` downcast semantics).
    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(t) = e.payload.as_deref().and_then(|p| p.downcast_ref::<T>()) {
                return Some(t);
            }
            cur = e.source.as_deref();
        }
        None
    }

    /// Whether the chain contains a `T` (see [`Error::downcast_ref`]).
    pub fn is<T: Any>(&self) -> bool {
        self.downcast_ref::<T>().is_some()
    }

    /// The error chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        items.into_iter()
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(s) = cur.source.as_deref() {
            cur = s;
        }
        &cur.msg
    }

    fn from_std(e: &(dyn std::error::Error)) -> Error {
        Error {
            msg: e.to_string(),
            source: e.source().map(|s| Box::new(Error::from_std(s))),
            payload: None,
        }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-separated (anyhow semantics).
            let mut first = true;
            for part in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{part}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for part in self.chain().skip(1) {
                write!(f, "\n    {part}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut err = Error::from_std(&e);
        err.payload = Some(Box::new(e));
        err
    }
}

/// Autoref-specialization machinery for the `anyhow!` macro (the same
/// construction the real crate uses): `anyhow!(value)` must *preserve* a
/// typed `std::error::Error` (so `bail!(InjectedFault { .. })` stays
/// downcastable) while still accepting any `Display` value as an ad-hoc
/// message. Method resolution picks `TraitKind` (by value, for
/// `Into<Error>` types) over `AdhocKind` (by reference, for everything
/// printable) without real specialization.
#[doc(hidden)]
pub mod kind {
    use super::Error;
    use std::fmt::Display;

    #[doc(hidden)]
    pub struct Adhoc;

    #[doc(hidden)]
    pub trait AdhocKind: Sized {
        fn anyhow_kind(&self) -> Adhoc {
            Adhoc
        }
    }
    impl<T: Display> AdhocKind for &T {}

    impl Adhoc {
        #[doc(hidden)]
        pub fn new<M: Display>(self, message: M) -> Error {
            Error::msg(message)
        }
    }

    #[doc(hidden)]
    pub struct Trait;

    #[doc(hidden)]
    pub trait TraitKind: Sized {
        fn anyhow_kind(&self) -> Trait {
            Trait
        }
    }
    impl<E: Into<Error>> TraitKind for E {}

    impl Trait {
        #[doc(hidden)]
        pub fn new<E: Into<Error>>(self, error: E) -> Error {
            error.into()
        }
    }
}

mod ext {
    use super::Error;

    /// Private extension enabling `Context` over both `std::error::Error`
    /// values and `Error` itself (the same sealed-trait construction the
    /// real `anyhow` uses; coherent because `Error` is not `std::error::
    /// Error`).
    pub trait StdError {
        fn ext_context(self, msg: String) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context(self, msg: String) -> Error {
            Error::from(self).context(msg)
        }
    }

    impl StdError for Error {
        fn ext_context(self, msg: String) -> Error {
            self.context(msg)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` and `Option`.
pub trait Context<T, E>: Sized {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| ext::StdError::ext_context(e, context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| ext::StdError::ext_context(e, f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        match $err {
            e => {
                #[allow(unused_imports)]
                use $crate::kind::{AdhocKind, TraitKind};
                (&e).anyhow_kind().new(e)
            }
        }
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err()).context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
        assert_eq!(e.root_cause(), "disk on fire");
    }

    #[test]
    fn option_context_and_macros() {
        let r: Result<i32> = None.with_context(|| format!("missing {}", "key"));
        assert_eq!(format!("{}", r.unwrap_err()), "missing key");
        fn inner(flag: bool) -> Result<i32> {
            ensure!(flag, "flag was {flag}");
            bail!("always fails with {}", 7);
        }
        assert_eq!(format!("{}", inner(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", inner(true).unwrap_err()), "always fails with 7");
        let owned = String::from("owned message");
        let e = anyhow!(owned.clone());
        assert_eq!(format!("{e}"), "owned message");
    }

    #[test]
    fn downcast_survives_context_and_macros() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        impl Display for Typed {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "typed error {}", self.0)
            }
        }
        impl std::error::Error for Typed {}

        // From / `?` conversion keeps the payload
        let e: Error = Typed(7).into();
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(e.is::<Typed>());
        // ... through context wrappers
        let wrapped = e.context("outer").context("outermost");
        assert_eq!(wrapped.downcast_ref::<Typed>(), Some(&Typed(7)));
        // ... and through the anyhow!/bail! value branch
        fn fails() -> Result<()> {
            bail!(Typed(9))
        }
        assert_eq!(fails().unwrap_err().downcast_ref::<Typed>(), Some(&Typed(9)));
        // Result::context on a typed error keeps it too
        let r: Result<(), _> = Err(Typed(3));
        let e = r.context("ctx").unwrap_err();
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(3)));
        // plain messages carry no payload
        assert!(anyhow!("just text {}", 1).downcast_ref::<Typed>().is_none());
        assert!(!Error::msg("x").is::<Typed>());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert!(parse("12").is_ok());
        assert!(parse("nope").is_err());
        let e = parse("nope").unwrap_err();
        assert!(format!("{e:?}").contains("invalid digit"));
    }
}
