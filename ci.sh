#!/usr/bin/env bash
# CI gate for the mergemoe workspace.
#
#   ./ci.sh            build + test + fmt + clippy + doc + quick bench + bench-diff
#   SKIP_LINT=1 ./ci.sh   skip fmt/clippy (bootstrap environments without
#                         rustfmt/clippy components installed)
#   SKIP_DOC=1 ./ci.sh    skip the rustdoc warning gate
#   SKIP_BENCH=1 ./ci.sh  skip the quick bench + bench-diff step
#
# Tier-1 (must always pass): cargo build --release && cargo test -q
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# Fault-injection sweep: rerun ONLY the chaos suites under a few seeded
# plans. Scoped to those test binaries on purpose — the rest of the suite
# reads MERGEMOE_FAULT through the default FromEnv setting and is meant to
# run fault-free. The registry suite additionally gets an io-fail crossing
# (varied per seed) so the crash-safety gates fire at different points; the
# variant-cache suite composes a build-fail crossing on top, so cold
# variant builds hit transient failures at seed-varied attempts.
for seed in 11 223 4099; do
    echo "==> fault-injection + continuous-batching suites under MERGEMOE_FAULT seed:$seed"
    MERGEMOE_FAULT="seed:$seed,transient:0.2,panic:0.05,slow:0.05,slow-ms:2" \
        cargo test -q --test fault_injection --test continuous_batching
    echo "==> registry chaos suite under MERGEMOE_FAULT seed:$seed"
    MERGEMOE_FAULT="seed:$seed,transient:0.2,slow:0.05,slow-ms:2,io-fail:$((seed % 7))" \
        cargo test -q --test registry
    echo "==> variant-cache chaos suite under MERGEMOE_FAULT seed:$seed (build-fail:$((seed % 5)), io-fail:$((seed % 7)))"
    MERGEMOE_FAULT="seed:$seed,transient:0.2,slow:0.05,slow-ms:2,build-fail:$((seed % 5)),io-fail:$((seed % 7))" \
        cargo test -q --test variant_cache
done

# Multi-lane chaos: the same suites with four compute lanes behind the
# collector, so lane supervision, drain, and the env-driven workload all
# run genuinely concurrent at least once per CI run.
echo "==> multi-lane chaos sweep (MERGEMOE_WORKERS=4, seed 31337)"
MERGEMOE_WORKERS=4 \
    MERGEMOE_FAULT="seed:31337,transient:0.2,panic:0.05,slow:0.05,slow-ms:2,build-fail:2" \
    cargo test -q --test fault_injection --test continuous_batching --test variant_cache

# Registry CLI smoke: add a synthetic variant to a scratch registry, list
# it, and verify its hashes end-to-end through the real binary.
echo "==> mergemoe registry smoke (add/ls/verify)"
REG_DIR=target/ci-registry
rm -rf "$REG_DIR"
./target/release/mergemoe registry add --registry "$REG_DIR" --model beta --name ci-smoke
./target/release/mergemoe registry ls --registry "$REG_DIR" | grep -q "ci-smoke@v1"
./target/release/mergemoe registry verify --registry "$REG_DIR"

# Serve smoke: the in-process demo load-gen end to end through the real
# binary (synthetic-model fallback on a bare checkout), once on the
# single-lane path and once with four lanes behind the collector.
for workers in 1 4; do
    echo "==> mergemoe serve smoke (--workers $workers)"
    SERVE_OUT="$(./target/release/mergemoe serve --model beta --engine native \
        --requests 40 --clients 4 --workers "$workers")"
    grep -q "served:" <<<"$SERVE_OUT"
done

# Routed-/score smoke through the real wire protocol (bash /dev/tcp, no
# curl dependency). Two short-lived servers:
#   1. default budget — a routed request cold-builds its variant on demand;
#   2. --cache-budget-mb 0 --route-fallback base — the variant can never be
#      admitted (507 first, quarantined after), so routed traffic is served
#      on the boot weights with the "fallback" marker.
serve_smoke() { # serve_smoke <extra-flags...> ; sets SMOKE_PID + PORT
    SMOKE_LOG=target/ci-serve-smoke.log
    ./target/release/mergemoe serve --model beta --engine native --workers 2 \
        --listen 127.0.0.1:0 "$@" >"$SMOKE_LOG" 2>&1 &
    SMOKE_PID=$!
    for _ in $(seq 100); do
        grep -q "listening on" "$SMOKE_LOG" && break
        sleep 0.2
    done
    PORT="$(sed -n 's#.*listening on http://[^:]*:\([0-9]*\).*#\1#p' "$SMOKE_LOG" | head -n1)"
    [[ -n "$PORT" ]] || { echo "serve smoke: no listen line"; cat "$SMOKE_LOG"; exit 1; }
}
post_score() { # post_score <json-body> ; prints the full HTTP response
    local body=$1
    exec 3<>"/dev/tcp/127.0.0.1/$PORT"
    printf 'POST /score HTTP/1.1\r\nHost: localhost\r\nContent-Length: %s\r\nConnection: close\r\n\r\n%s' \
        "${#body}" "$body" >&3
    cat <&3
    exec 3>&-
}
ROUTED_BODY='{"prompt":"c:abcd|","completion":"abcd.","method":"mergemoe","ratio":0.5,"calib_source":"mixture"}'

get_path() { # get_path </path> ; prints the full HTTP response
    exec 3<>"/dev/tcp/127.0.0.1/$PORT"
    printf 'GET %s HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n' "$1" >&3
    cat <&3
    exec 3>&-
}

echo "==> mergemoe serve routed-/score smoke (cold build)"
serve_smoke
post_score "$ROUTED_BODY" | grep -q '"score"'        # cold: built on demand
post_score "$ROUTED_BODY" | grep -q '"score"'        # warm: served from cache
get_path /metrics | grep -q "mergemoe_cache_builds_total 1"
kill "$SMOKE_PID" 2>/dev/null || true
wait "$SMOKE_PID" 2>/dev/null || true

echo "==> mergemoe serve routed-/score smoke (quarantine -> base fallback)"
serve_smoke --cache-budget-mb 0 --route-fallback base
post_score "$ROUTED_BODY" | grep -q "HTTP/1.1 507"   # typed budget rejection
post_score "$ROUTED_BODY" | grep -q '"fallback"'     # quarantined -> boot weights, marked
kill "$SMOKE_PID" 2>/dev/null || true
wait "$SMOKE_PID" 2>/dev/null || true

# Generate smoke: seeded sampling through the KV-cache decode path must
# reproduce the exact token sequence across runs AND thread counts (the
# decode forward is thread-invariant; tests/decode_consistency.rs pins the
# bit-identity, this pins the end-to-end binary).
echo "==> mergemoe generate smoke (seeded, --threads 1 vs 8)"
GEN_FLAGS=(--model beta --engine native --prompt "c:abcd|" \
    --max-new 24 --temp 0.8 --top-k 8 --top-p 0.9 --seed 7)
GEN_T1="$(./target/release/mergemoe generate "${GEN_FLAGS[@]}" --threads 1 | grep '^tokens:')"
GEN_T8="$(./target/release/mergemoe generate "${GEN_FLAGS[@]}" --threads 8 | grep '^tokens:')"
[[ -n "$GEN_T1" ]] || { echo "generate smoke: no tokens line"; exit 1; }
[[ "$GEN_T1" == "$GEN_T8" ]] || {
    echo "generate smoke: token sequence differs across thread counts"
    echo "  t1: $GEN_T1"
    echo "  t8: $GEN_T8"
    exit 1
}

if [[ "${SKIP_LINT:-0}" != "1" ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy -D warnings"
    cargo clippy --all-targets -- -D warnings
fi

if [[ "${SKIP_DOC:-0}" != "1" ]]; then
    # Docs gate: every rustdoc warning (missing docs under the
    # #![warn(missing_docs)] modules, broken intra-doc links, bad code
    # fences) fails CI, so documentation debt cannot re-accumulate.
    echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
    RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D warnings" cargo doc --no-deps --offline
fi

if [[ "${SKIP_BENCH:-0}" != "1" ]]; then
    # Perf trajectory: one quick-mode bench on every run, diffed against the
    # committed baseline so regressions surface in CI output, not archaeology.
    echo "==> quick bench (bench_par + bench_gemm + bench_forward + bench_decode)"
    REPORT_DIR=target/bench-reports
    mkdir -p "$REPORT_DIR"
    MERGEMOE_BENCH_QUICK=1 MERGEMOE_BENCH_DIR="$REPORT_DIR" cargo bench --bench bench_par
    # Kernel trajectory: scalar-vs-SIMD GEMM sweep, so the per-core win (or
    # a regression in it) lands in every PR's perf report.
    MERGEMOE_BENCH_QUICK=1 MERGEMOE_BENCH_DIR="$REPORT_DIR" cargo bench --bench bench_gemm
    # Zero-alloc gate: the counting-allocator probes (serving loop + sweep
    # scorer path + autoregressive decode loop) hard-fail the run on any
    # steady-state allocation.
    MERGEMOE_BENCH_QUICK=1 MERGEMOE_BENCH_DIR="$REPORT_DIR" MERGEMOE_STRICT_ALLOC=1 \
        cargo bench --bench bench_forward
    # Decode trajectory: prefill vs KV-cache decode vs re-prefill fallback
    # tokens/sec, so the O(S)-per-token win lands in every PR's perf report.
    MERGEMOE_BENCH_QUICK=1 MERGEMOE_BENCH_DIR="$REPORT_DIR" cargo bench --bench bench_decode

    if ls benches/baseline/BENCH_*.json >/dev/null 2>&1; then
        # --max-regress makes the diff a gate: >15% p50 regression on any
        # benchmark (baseline p50 >= 100µs; smaller entries are quick-mode
        # noise) exits nonzero instead of only printing.
        echo "==> bench-diff vs benches/baseline (gate: 15% p50 regression)"
        cargo run --release --bin bench_diff -- --max-regress 15 benches/baseline "$REPORT_DIR"
    else
        # Reference-runner path: the first run on a machine captures its
        # reports as the pinned baseline; commit benches/baseline/*.json on
        # the reference runner so bench_diff has a trajectory (ephemeral
        # runners re-capture and effectively diff against themselves).
        echo "==> no benches/baseline yet — capturing this run as the baseline"
        mkdir -p benches/baseline
        cp "$REPORT_DIR"/BENCH_*.json benches/baseline/
        echo "    (commit benches/baseline/*.json to pin the trajectory)"
    fi
fi

echo "ci: OK"
