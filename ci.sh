#!/usr/bin/env bash
# CI gate for the mergemoe workspace.
#
#   ./ci.sh            build + test + fmt + clippy
#   SKIP_LINT=1 ./ci.sh   build + test only (bootstrap environments without
#                         rustfmt/clippy components installed)
#
# Tier-1 (must always pass): cargo build --release && cargo test -q
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

if [[ "${SKIP_LINT:-0}" != "1" ]]; then
    echo "==> cargo fmt --check"
    cargo fmt --check

    echo "==> cargo clippy -D warnings"
    cargo clippy --all-targets -- -D warnings
fi

echo "ci: OK"
